//! Crash matrix for the storage lifecycle (checkpoint compaction +
//! journal rotation): recovery must be byte-exact no matter where in
//! the checkpoint protocol a batch job is killed.
//!
//! The protocol has three windows a kill can land in:
//!   1. during the checkpoint *write* — `store.ckpt.tmp` is partial,
//!      the rename never ran, the old checkpoint is authoritative;
//!   2. between the write and the *swap* — `store.ckpt.tmp` is complete
//!      but unrenamed, same outcome as (1);
//!   3. after the swap, during the *truncation* — covered journal
//!      segments survive on disk and replay must skip (and delete)
//!      them, or documents would be applied twice.
//!
//! Plus the headline property: under sustained ingest writing several
//! times the compaction threshold, the on-disk journal stays bounded
//! and post-crash recovery replays only the post-checkpoint tail.

use std::path::Path;

use hpcstore::config::{ShardKeyKind, StoreConfig};
use hpcstore::metrics::Registry;
use hpcstore::mongo::bson::Document;
use hpcstore::mongo::cluster::{Cluster, ClusterSpec};
use hpcstore::mongo::query::Filter;
use hpcstore::mongo::storage::{Engine, EngineOptions, LocalDir, StorageDir};
use hpcstore::mongo::wire::{rpc, ShardRequest};
use hpcstore::runtime::Kernels;
use hpcstore::util::ids::ShardId;

fn doc(i: u64) -> Document {
    Document::new()
        .set("ts", i as i64)
        .set("node_id", (i % 16) as i64)
        .set("m0", i as f64 * 0.5)
        .set("m1", (i * 31) as f64)
}

fn batch(lo: u64, n: u64) -> Vec<Document> {
    (lo..lo + n).map(doc).collect()
}

fn lifecycle(checkpoint_bytes: u64) -> EngineOptions {
    EngineOptions {
        journal: true,
        compress_checkpoints: true,
        checkpoint_bytes,
        journal_segments: 4,
        full_checkpoint_chain: 4,
        ..EngineOptions::default()
    }
}

/// Manual-checkpoint options with an explicit rebase threshold (delta
/// lifecycle under test control).
fn manual(full_checkpoint_chain: u32) -> EngineOptions {
    EngineOptions {
        journal: true,
        compress_checkpoints: false,
        checkpoint_bytes: 0,
        journal_segments: 4,
        full_checkpoint_chain,
        ..EngineOptions::default()
    }
}

/// Sum of on-disk `journal-*.wal` sizes under `root`.
fn journal_files_bytes(root: &str) -> u64 {
    std::fs::read_dir(root)
        .unwrap()
        .filter_map(|e| {
            let e = e.unwrap();
            let name = e.file_name().to_string_lossy().into_owned();
            (name.starts_with("journal-") && name.ends_with(".wal"))
                .then(|| e.metadata().unwrap().len())
        })
        .sum()
}

// lint: journal-op(OP_INSERT_MANY) — every batch below is one multi-record
// journal frame whose replay is differentially checked after each kill.
#[test]
fn sustained_ingest_bounds_disk_and_replays_only_the_tail() {
    let threshold: u64 = 64 * 1024;
    let opts = lifecycle(threshold);
    let seg = opts.segment_bytes();
    let dir = LocalDir::temp("cm-bound").unwrap();
    let root = dir.describe();
    let mut total = 0u64;
    {
        let mut eng = Engine::open_with(Box::new(dir), opts.clone()).unwrap();
        eng.create_collection("metrics");
        // Write well past 4x the compaction threshold, the shard-server
        // pattern: group commit, then the background compaction hook.
        let mut written = 0u64;
        while written < 4 * threshold {
            let docs = batch(total, 64);
            total += 64;
            eng.insert_many("metrics", &docs).unwrap();
            let frame = eng.pending_journal_bytes() as u64;
            eng.sync().unwrap();
            written += frame;
            eng.maybe_checkpoint().unwrap();
            // Bounded steady state: at most one threshold plus one
            // segment of journal on disk, in memory and in real files.
            assert!(
                eng.journal_disk_bytes() <= threshold + seg,
                "engine journal {} exceeds bound",
                eng.journal_disk_bytes()
            );
            assert!(
                journal_files_bytes(&root) <= threshold + seg,
                "on-disk journal {} exceeds bound",
                journal_files_bytes(&root)
            );
        }
        assert!(eng.generation() >= 3, "expected repeated compaction");
        // Drop without checkpoint = kill.
    }
    let eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
    assert_eq!(eng.stats("metrics").docs, total, "recovery must be exact");
    let rep = eng.recovery_report();
    assert!(rep.checkpoint_generation >= 3);
    assert!(
        rep.bytes_replayed <= threshold + seg,
        "replayed {} bytes — recovery must be tail-only, not O(total writes)",
        rep.bytes_replayed
    );
}

#[test]
fn kill_during_checkpoint_write_keeps_old_checkpoint_authoritative() {
    let dir = LocalDir::temp("cm-write").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
        eng.create_collection("metrics");
        eng.insert_many("metrics", &batch(0, 20)).unwrap();
        eng.sync().unwrap();
        eng.checkpoint().unwrap(); // generation 1, the survivor
        eng.insert_many("metrics", &batch(20, 10)).unwrap();
        eng.sync().unwrap();
        // Killed mid-way through writing the generation-2 checkpoint:
        // a partial staging file is on disk, the rename never happened.
    }
    std::fs::write(
        Path::new(&root).join("store.ckpt.tmp"),
        b"HPCCKPT2\x02partial garbage from a dying writer",
    )
    .unwrap();
    let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    assert_eq!(eng.stats("metrics").docs, 30);
    assert_eq!(eng.recovery_report().checkpoint_generation, 1);
    assert!(
        !Path::new(&root).join("store.ckpt.tmp").exists(),
        "recovery must discard the partial staging file"
    );
}

#[test]
fn kill_between_checkpoint_write_and_swap_keeps_old_checkpoint() {
    let dir = LocalDir::temp("cm-swap").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
        eng.create_collection("metrics");
        eng.insert_many("metrics", &batch(0, 15)).unwrap();
        eng.sync().unwrap();
        eng.checkpoint().unwrap();
        eng.insert_many("metrics", &batch(15, 5)).unwrap();
        eng.sync().unwrap();
    }
    // A *complete* staging file that was never renamed: even a fully
    // valid unrenamed checkpoint must be ignored — only the rename
    // publishes it.
    let published = std::fs::read(Path::new(&root).join("store.ckpt")).unwrap();
    std::fs::write(Path::new(&root).join("store.ckpt.tmp"), &published).unwrap();
    let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    assert_eq!(eng.stats("metrics").docs, 20);
    assert_eq!(eng.recovery_report().checkpoint_generation, 1);
    assert!(!Path::new(&root).join("store.ckpt.tmp").exists());
}

#[test]
fn kill_during_truncate_skips_and_deletes_covered_segments() {
    let dir = LocalDir::temp("cm-trunc").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
        eng.create_collection("metrics");
        eng.insert_many("metrics", &batch(0, 25)).unwrap();
        eng.sync().unwrap();
        // Keep a copy of the covered segment, checkpoint (which
        // truncates it), then put it back — exactly the disk state a
        // kill between the swap and the end of truncation leaves.
        let seg1 = std::fs::read(Path::new(&root).join("journal-000001.wal")).unwrap();
        let ck = eng.checkpoint().unwrap();
        assert!(ck.segments_truncated >= 1);
        assert!(!Path::new(&root).join("journal-000001.wal").exists());
        std::fs::write(Path::new(&root).join("journal-000001.wal"), &seg1).unwrap();
        eng.insert_many("metrics", &batch(25, 5)).unwrap();
        eng.sync().unwrap();
    }
    let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    // Replaying the covered segment would double-apply its 25 documents.
    assert_eq!(eng.stats("metrics").docs, 30, "covered segment must not replay");
    let rep = eng.recovery_report();
    assert_eq!(rep.segments_skipped, 1);
    assert!(
        !Path::new(&root).join("journal-000001.wal").exists(),
        "recovery must finish the interrupted truncation"
    );
}

#[test]
fn recovery_replays_only_post_checkpoint_segments() {
    // Regression for the watermark logic: frames before the checkpoint
    // never replay, frames after it always do.
    let dir = LocalDir::temp("cm-tail").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
        eng.create_collection("metrics");
        for b in 0..5 {
            eng.insert_many("metrics", &batch(b * 8, 8)).unwrap();
            eng.sync().unwrap();
        }
        eng.checkpoint().unwrap();
        eng.insert_many("metrics", &batch(40, 7)).unwrap();
        eng.sync().unwrap();
    }
    let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    assert_eq!(eng.stats("metrics").docs, 47);
    let rep = eng.recovery_report();
    assert_eq!(rep.checkpoint_generation, 1);
    assert_eq!(rep.segments_replayed, 1, "only the tail segment");
    assert_eq!(rep.frames_replayed, 1, "only the post-checkpoint frame");
}

#[test]
fn legacy_single_file_journal_migrates_into_the_lifecycle() {
    let dir = LocalDir::temp("cm-legacy").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
        eng.create_collection("metrics");
        eng.insert_many("metrics", &batch(0, 12)).unwrap();
        eng.sync().unwrap();
    }
    // Rewrite the segment as the pre-rotation single-file layout.
    std::fs::rename(
        Path::new(&root).join("journal-000001.wal"),
        Path::new(&root).join("journal.wal"),
    )
    .unwrap();
    {
        let mut eng =
            Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("metrics").docs, 12, "legacy journal must replay");
        let ck = eng.checkpoint().unwrap();
        assert!(ck.segments_truncated >= 1);
        assert!(
            !Path::new(&root).join("journal.wal").exists(),
            "checkpoint covers and removes the legacy journal"
        );
    }
    let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    assert_eq!(eng.stats("metrics").docs, 12);
    assert_eq!(eng.recovery_report().frames_replayed, 0);
}

#[test]
fn kill_after_swap_during_legacy_removal_does_not_double_apply() {
    // Migration window: the first v2 checkpoint already contains the
    // legacy journal's documents; a kill between the swap and the
    // legacy file's removal must not lead to a double replay.
    let dir = LocalDir::temp("cm-legacy-swap").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
        eng.create_collection("metrics");
        eng.insert_many("metrics", &batch(0, 10)).unwrap();
        eng.sync().unwrap();
    }
    std::fs::rename(
        Path::new(&root).join("journal-000001.wal"),
        Path::new(&root).join("journal.wal"),
    )
    .unwrap();
    let legacy = std::fs::read(Path::new(&root).join("journal.wal")).unwrap();
    {
        let mut eng =
            Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("metrics").docs, 10);
        eng.checkpoint().unwrap(); // publishes v2 and removes journal.wal
    }
    // Put the legacy file back: the kill landed mid-removal.
    std::fs::write(Path::new(&root).join("journal.wal"), &legacy).unwrap();
    let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    assert_eq!(
        eng.stats("metrics").docs,
        10,
        "legacy journal covered by a v2 checkpoint must not replay"
    );
    assert!(
        !Path::new(&root).join("journal.wal").exists(),
        "recovery must finish the interrupted legacy removal"
    );
}

#[test]
fn kill_during_delta_write_keeps_published_chain_authoritative() {
    // A kill while a delta checkpoint is being staged leaves a partial
    // `delta-NNNNNN.ckpt.tmp`: the rename never ran, so the published
    // chain (base + earlier deltas) plus the journal tail is the truth.
    let dir = LocalDir::temp("cm-delta-write").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
        eng.create_collection("metrics");
        eng.insert_many("metrics", &batch(0, 20)).unwrap();
        eng.sync().unwrap();
        eng.checkpoint().unwrap(); // gen 1: full
        eng.insert_many("metrics", &batch(20, 5)).unwrap();
        eng.sync().unwrap();
        eng.checkpoint().unwrap(); // gen 2: delta
        eng.insert_many("metrics", &batch(25, 5)).unwrap();
        eng.sync().unwrap();
        // Killed mid-way through staging the gen-3 delta.
    }
    let d2 = std::fs::read(Path::new(&root).join("delta-000002.ckpt")).unwrap();
    std::fs::write(Path::new(&root).join("delta-000003.ckpt.tmp"), &d2[..d2.len() / 2])
        .unwrap();
    let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    assert_eq!(eng.stats("metrics").docs, 30);
    let rep = eng.recovery_report();
    assert_eq!(rep.checkpoint_generation, 2);
    assert_eq!(rep.deltas_folded, 1);
    assert_eq!(rep.frames_replayed, 1, "the uncheckpointed tail still replays");
    assert!(
        !Path::new(&root).join("delta-000003.ckpt.tmp").exists(),
        "recovery must discard the partial delta staging file"
    );
}

#[test]
fn kill_during_rebase_cleanup_never_refolds_superseded_chain() {
    // A rebase publishes the new full snapshot (atomic rename) and then
    // deletes the old chain. A kill between the two leaves stale deltas
    // next to a newer base; folding them would double-apply every
    // record they carry.
    let opts = manual(2);
    let dir = LocalDir::temp("cm-rebase").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open_with(Box::new(dir), opts.clone()).unwrap();
        eng.create_collection("metrics");
        eng.insert_many("metrics", &batch(0, 10)).unwrap();
        eng.sync().unwrap();
        assert!(eng.checkpoint().unwrap().full); // gen 1
        eng.insert_many("metrics", &batch(10, 5)).unwrap();
        eng.sync().unwrap();
        assert!(!eng.checkpoint().unwrap().full); // gen 2: delta
        eng.insert_many("metrics", &batch(15, 5)).unwrap();
        eng.sync().unwrap();
        assert!(!eng.checkpoint().unwrap().full); // gen 3: delta
        let d2 = std::fs::read(Path::new(&root).join("delta-000002.ckpt")).unwrap();
        let d3 = std::fs::read(Path::new(&root).join("delta-000003.ckpt")).unwrap();
        eng.insert_many("metrics", &batch(20, 5)).unwrap();
        eng.sync().unwrap();
        let ck = eng.checkpoint().unwrap(); // gen 4: rebase
        assert!(ck.full);
        assert!(!Path::new(&root).join("delta-000002.ckpt").exists());
        // Put the superseded chain back: the kill landed after the swap
        // but before the chain cleanup finished.
        std::fs::write(Path::new(&root).join("delta-000002.ckpt"), &d2).unwrap();
        std::fs::write(Path::new(&root).join("delta-000003.ckpt"), &d3).unwrap();
    }
    let eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
    assert_eq!(
        eng.stats("metrics").docs,
        25,
        "stale chain under a newer base must not refold"
    );
    let rep = eng.recovery_report();
    assert_eq!(rep.checkpoint_generation, 4);
    assert_eq!(rep.deltas_folded, 0);
    for g in [2u64, 3] {
        assert!(
            !Path::new(&root).join(format!("delta-{g:06}.ckpt")).exists(),
            "recovery must finish the interrupted chain cleanup (delta {g})"
        );
    }
}

#[test]
fn restart_mid_chain_folds_deltas_and_tail_each_cycle() {
    // Job-queue reality under the delta lifecycle: every allocation
    // dies mid-chain with a journal tail beyond the newest delta. Each
    // restart must fold base + chain + tail exactly, and the next delta
    // must absorb the replayed tail.
    let opts = manual(16);
    let root = LocalDir::temp("cm-mid-chain").unwrap().describe();
    let mut total = 0u64;
    for cycle in 0..5u64 {
        let mut eng =
            Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts.clone()).unwrap();
        eng.create_collection("metrics");
        assert_eq!(eng.stats("metrics").docs, total, "cycle {cycle} lost data");
        if cycle > 0 {
            let rep = eng.recovery_report();
            assert_eq!(rep.checkpoint_generation, cycle);
            assert_eq!(rep.deltas_folded, cycle - 1, "cycle {cycle} chain length");
            assert_eq!(rep.frames_replayed, 1, "cycle {cycle} replays one tail frame");
        }
        eng.insert_many("metrics", &batch(total, 8)).unwrap();
        total += 8;
        eng.sync().unwrap();
        eng.checkpoint().unwrap(); // cycle c writes generation c+1
        eng.insert_many("metrics", &batch(total, 4)).unwrap();
        total += 4;
        eng.sync().unwrap();
        // Kill with a tail beyond the newest delta.
    }
    let eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
    assert_eq!(eng.stats("metrics").docs, total);
    assert_eq!(eng.recovery_report().deltas_folded, 4);
    assert_eq!(eng.recovery_report().checkpoint_generation, 5);
}

#[test]
fn v2_store_opens_upgrades_and_chains_without_double_apply() {
    // Build a store, then rewrite its checkpoint into the legacy
    // `HPCCKPT2` layout (same body, pre-delta header) — exactly what a
    // PR-2-era job left on the shared filesystem.
    let dir = LocalDir::temp("cm-v2").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
        eng.create_collection("metrics");
        eng.insert_many("metrics", &batch(0, 20)).unwrap();
        eng.sync().unwrap();
        eng.checkpoint().unwrap(); // v3 full, gen 1
        eng.insert_many("metrics", &batch(20, 6)).unwrap();
        eng.sync().unwrap(); // post-checkpoint tail
    }
    let ckpt = Path::new(&root).join("store.ckpt");
    let v3 = std::fs::read(&ckpt).unwrap();
    assert_eq!(&v3[..8], b"HPCCKPT3");
    assert_eq!(v3[8], 0, "store.ckpt must be a full snapshot");
    let mut v2 = b"HPCCKPT2".to_vec();
    v2.extend_from_slice(&v3[9..17]); // generation
    v2.extend_from_slice(&v3[25..33]); // covered_seq (drop base_generation)
    v2.extend_from_slice(&v3[33..]); // compressed flag + body
    std::fs::write(&ckpt, &v2).unwrap();

    // The v2 store opens: base loads, the tail replays exactly once,
    // and the first new checkpoint is a *delta* chaining directly on
    // the legacy base generation — no forced full rewrite.
    let mut eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    assert_eq!(eng.stats("metrics").docs, 26);
    assert_eq!(eng.recovery_report().checkpoint_generation, 1);
    assert_eq!(eng.recovery_report().frames_replayed, 1);
    eng.insert_many("metrics", &batch(26, 4)).unwrap();
    eng.sync().unwrap();
    let ck = eng.checkpoint().unwrap(); // gen 2: delta over the v2 base
    assert!(!ck.full, "upgrading a v2 store must not force a full snapshot");
    drop(eng);

    // Mixed store (v2 base + v3 delta): the tail the delta covers was
    // truncated with it — nothing may double-apply.
    let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
    assert_eq!(eng.stats("metrics").docs, 30, "v2 base + v3 delta must fold exactly");
    let rep = eng.recovery_report();
    assert_eq!(rep.checkpoint_generation, 2);
    assert_eq!(rep.deltas_folded, 1);
    assert_eq!(rep.frames_replayed, 0);
}

#[test]
fn compaction_trigger_accumulates_across_restarts() {
    // Each job writes only ~half the threshold and is then killed. The
    // replayed tail must seed the compaction trigger, so the *second*
    // job crosses the threshold and compacts — otherwise sub-threshold
    // jobs would grow the journal (and replay cost) without bound.
    let opts = lifecycle(32 * 1024);
    let root = LocalDir::temp("cm-trigger").unwrap().describe();
    let mut total = 0u64;
    for _cycle in 0..6 {
        let mut eng =
            Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts.clone()).unwrap();
        eng.create_collection("metrics");
        let mut written = 0u64;
        while written < 16 * 1024 {
            eng.insert_many("metrics", &batch(total, 32)).unwrap();
            total += 32;
            let frame = eng.pending_journal_bytes() as u64;
            eng.sync().unwrap();
            written += frame;
            eng.maybe_checkpoint().unwrap();
        }
        // Kill (drop) — no teardown checkpoint.
    }
    let eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts.clone()).unwrap();
    assert_eq!(eng.stats("metrics").docs, total);
    assert!(
        eng.generation() >= 2,
        "cumulative tail bytes across restarts must trigger compaction, got generation {}",
        eng.generation()
    );
    // Replay stays bounded by roughly one threshold + one cycle, never
    // the whole history.
    assert!(
        eng.recovery_report().bytes_replayed
            <= opts.checkpoint_bytes + opts.segment_bytes() + 16 * 1024,
        "replayed {} bytes",
        eng.recovery_report().bytes_replayed
    );
}

#[test]
fn lifecycle_survives_repeated_kill_restart_cycles() {
    // Job-queue reality: every allocation ends in a kill. Run several
    // ingest-kill-recover cycles with compaction active and verify the
    // store is exact at every generation.
    let opts = lifecycle(32 * 1024);
    let root;
    {
        let dir = LocalDir::temp("cm-cycles").unwrap();
        root = dir.describe();
        let mut eng = Engine::open_with(Box::new(dir), opts.clone()).unwrap();
        eng.create_collection("metrics");
        eng.sync().unwrap();
    }
    let mut total = 0u64;
    for cycle in 0..5 {
        let mut eng =
            Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts.clone()).unwrap();
        eng.create_collection("metrics");
        assert_eq!(eng.stats("metrics").docs, total, "cycle {cycle} lost data");
        for b in 0..20 {
            eng.insert_many("metrics", &batch(total, 32)).unwrap();
            total += 32;
            eng.sync().unwrap();
            if b % 3 == 0 {
                eng.maybe_checkpoint().unwrap();
            }
        }
        // Kill (drop) — no teardown checkpoint.
    }
    let eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
    assert_eq!(eng.stats("metrics").docs, total);
}

// ---------------------------------------------------------------------------
// Migration kill windows (streaming chunk migration — see
// `sharding::migration` and docs/ARCHITECTURE.md §6).
//
// A two-shard cluster with a ranged key and a single-node corpus puts
// every document into chunk 0 on shard 0. Each test drives the
// migration wire protocol by hand up to a precise M-state, "kills" the
// job (shutdown without a teardown checkpoint — storage-wise identical
// to a walltime kill, since every protocol step is group-committed),
// restarts on the same directories, and asserts the reconciliation
// pass leaves exactly-once data: no document lost, none duplicated.

/// Chunk 0 of a 2-shard × 1-chunk ranged pre-split covers positions
/// `[0, u64::MAX / 2]`.
const CHUNK0: (u64, u64) = (0, u64::MAX / 2);

fn mig_doc(ts: i64) -> Document {
    Document::new().set("ts", ts).set("node_id", 5i64).set("m0", ts as f64)
}

fn mig_spec() -> ClusterSpec {
    let mut spec = ClusterSpec::small(2, 1);
    spec.chunks_per_shard = 1;
    spec.store = StoreConfig {
        shard_key: ShardKeyKind::Ranged,
        balancer: false, // the protocol is driven by hand here
        ..Default::default()
    };
    spec
}

fn mig_roots(label: &str) -> Vec<String> {
    (0..2)
        .map(|i| LocalDir::temp(&format!("{label}-{i}")).unwrap().describe())
        .collect()
}

fn mig_cluster(roots: &[String]) -> Cluster {
    let roots = roots.to_vec();
    Cluster::start(
        mig_spec(),
        move |sid| Ok(Box::new(LocalDir::new(&roots[sid.index()])?)),
        Kernels::fallback(),
        Registry::new(),
    )
    .unwrap()
}

/// Stream `limit`-sized batches of CHUNK0 from shard 0 into shard 1's
/// staging; stop early after `max_batches` (`None` = drain the range).
/// Returns the number of documents staged.
fn stream_batches(cluster: &Cluster, limit: usize, max_batches: Option<usize>) -> u64 {
    let shards = cluster.shard_mailboxes();
    let mut after = None;
    let mut staged = 0u64;
    let mut batches = 0usize;
    loop {
        let rep = rpc(&shards[0], |reply| ShardRequest::MigrateBatch {
            range: CHUNK0,
            after,
            limit,
            reply,
        })
        .unwrap()
        .unwrap();
        if let Some(last) = rep.last {
            after = Some(last);
        }
        if !rep.docs.is_empty() {
            staged += rep.docs.len() as u64;
            rpc(&shards[1], |reply| ShardRequest::StageChunk {
                range: CHUNK0,
                from: ShardId(0),
                docs: rep.docs,
                reply,
            })
            .unwrap()
            .unwrap();
            batches += 1;
        }
        if rep.done {
            break;
        }
        if let Some(mx) = max_batches {
            if batches >= mx {
                break;
            }
        }
    }
    staged
}

#[test]
fn kill_during_migration_stream_rolls_back_without_dup_or_loss() {
    let roots = mig_roots("mig-stream");
    {
        let cluster = mig_cluster(&roots);
        let client = cluster.client();
        client.insert_many((0..600).map(mig_doc).collect()).unwrap();
        // Kill mid-stream: three 64-doc batches staged, no commit.
        let staged = stream_batches(&cluster, 64, Some(3));
        assert_eq!(staged, 192);
        cluster.shutdown();
    }
    {
        // Restart: reconciliation must roll the uncommitted staging
        // back — the donor still owns every document.
        let cluster = mig_cluster(&roots);
        assert_eq!(
            cluster.metrics().counter("cluster.migrations_rolled_back").get(),
            1
        );
        let client = cluster.client();
        assert_eq!(client.count_documents(Filter::True).unwrap(), 600);
        let stats = cluster.stats();
        assert_eq!(stats.per_shard_docs, vec![600, 0], "partial copy must be dropped");
        for s in cluster.shard_stats() {
            assert_eq!(s.staged_docs, 0);
        }
        cluster.shutdown();
    }
    {
        // Reconciliation is idempotent: a third job finds nothing to do.
        let cluster = mig_cluster(&roots);
        assert_eq!(
            cluster.metrics().counter("cluster.migrations_rolled_back").get(),
            0
        );
        assert_eq!(cluster.client().count_documents(Filter::True).unwrap(), 600);
        cluster.shutdown();
    }
}

#[test]
fn kill_between_commit_marker_and_source_delete_rolls_forward() {
    let roots = mig_roots("mig-marker");
    {
        let cluster = mig_cluster(&roots);
        let client = cluster.client();
        client.insert_many((0..500).map(mig_doc).collect()).unwrap();
        let staged = stream_batches(&cluster, 128, None);
        assert_eq!(staged, 500);
        // The durable commit marker — the roll-forward point — then the
        // kill lands before the source delete ever runs.
        let n = rpc(&cluster.shard_mailboxes()[1], |reply| ShardRequest::CommitStaged {
            reply,
        })
        .unwrap()
        .unwrap();
        assert_eq!(n, 500);
        cluster.shutdown();
    }
    {
        let cluster = mig_cluster(&roots);
        assert_eq!(cluster.metrics().counter("cluster.migrations_recovered").get(), 1);
        let client = cluster.client();
        assert_eq!(
            client.count_documents(Filter::True).unwrap(),
            500,
            "roll-forward must neither lose nor duplicate"
        );
        let stats = cluster.stats();
        assert_eq!(stats.per_shard_docs, vec![0, 500], "data must end on the destination");
        let shard_stats = cluster.shard_stats();
        assert_eq!(shard_stats[1].staged_docs, 0);
        // The recovery's source delete carries the triggered compaction:
        // the moved-away documents left the donor's journal too.
        assert_eq!(
            shard_stats[0].journal_disk_bytes, 0,
            "post-delete compaction must truncate the donor journal"
        );
        cluster.shutdown();
    }
    {
        let cluster = mig_cluster(&roots);
        assert_eq!(cluster.metrics().counter("cluster.migrations_recovered").get(), 0);
        assert_eq!(cluster.client().count_documents(Filter::True).unwrap(), 500);
        cluster.shutdown();
    }
}

// lint: journal-op(OP_REMOVE_MANY) — the source delete is one atomic
// remove_many frame; this kill point replays it against the staged copy.
// lint: journal-op(OP_MOVE_MANY) — recovery's publish replays the staged →
// live move_many frame after the kill.
#[test]
fn kill_between_source_delete_and_publish_rolls_forward() {
    let roots = mig_roots("mig-delete");
    {
        let cluster = mig_cluster(&roots);
        let client = cluster.client();
        client.insert_many((0..400).map(mig_doc).collect()).unwrap();
        assert_eq!(stream_batches(&cluster, 100, None), 400);
        let shards = cluster.shard_mailboxes();
        rpc(&shards[1], |reply| ShardRequest::CommitStaged { reply })
            .unwrap()
            .unwrap();
        // The source delete runs (one atomic remove_many frame +
        // compaction), then the kill lands before the publish.
        let del = rpc(&shards[0], |reply| ShardRequest::DeleteChunk {
            range: CHUNK0,
            compact: true,
            reply,
        })
        .unwrap()
        .unwrap();
        assert_eq!(del.removed, 400);
        assert!(del.compacted.is_some());
        cluster.shutdown();
    }
    {
        let cluster = mig_cluster(&roots);
        let client = cluster.client();
        assert_eq!(
            client.count_documents(Filter::True).unwrap(),
            400,
            "the staged copy is the only copy — publish must finish"
        );
        assert_eq!(cluster.stats().per_shard_docs, vec![0, 400]);
        for s in cluster.shard_stats() {
            assert_eq!(s.staged_docs, 0);
        }
        cluster.shutdown();
    }
}

#[test]
fn kill_between_publish_and_source_delete_rolls_forward() {
    let roots = mig_roots("mig-publish");
    {
        let cluster = mig_cluster(&roots);
        let client = cluster.client();
        client.insert_many((0..350).map(mig_doc).collect()).unwrap();
        assert_eq!(stream_batches(&cluster, 100, None), 350);
        let shards = cluster.shard_mailboxes();
        rpc(&shards[1], |reply| ShardRequest::CommitStaged { reply })
            .unwrap()
            .unwrap();
        // The live M4 order publishes FIRST (the orphan-read fix): the
        // destination goes live while the donor still holds its copy,
        // and the kill lands before the donor delete or ClearStaged.
        let n = rpc(&shards[1], |reply| ShardRequest::PublishStaged { reply })
            .unwrap()
            .unwrap();
        assert_eq!(n, 350);
        cluster.shutdown();
    }
    {
        // Restart: the drained staging meta + marker survive, so
        // recovery rolls forward — the donor delete removes the orphan
        // copy, the re-publish moves nothing, ClearStaged retires the
        // meta. No document is lost or duplicated.
        let cluster = mig_cluster(&roots);
        assert_eq!(cluster.metrics().counter("cluster.migrations_recovered").get(), 1);
        let client = cluster.client();
        assert_eq!(
            client.count_documents(Filter::True).unwrap(),
            350,
            "recovery must delete the donor's orphan copy exactly once"
        );
        assert_eq!(cluster.stats().per_shard_docs, vec![0, 350]);
        for s in cluster.shard_stats() {
            assert_eq!(s.staged_docs, 0);
        }
        cluster.shutdown();
    }
    {
        // Idempotent: a third job finds nothing to reconcile.
        let cluster = mig_cluster(&roots);
        assert_eq!(cluster.metrics().counter("cluster.migrations_recovered").get(), 0);
        assert_eq!(cluster.client().count_documents(Filter::True).unwrap(), 350);
        cluster.shutdown();
    }
}

#[test]
fn kill_during_post_delete_compaction_recovers_exactly() {
    let roots = mig_roots("mig-compact");
    {
        let cluster = mig_cluster(&roots);
        let client = cluster.client();
        client.insert_many((0..300).map(mig_doc).collect()).unwrap();
        assert_eq!(stream_batches(&cluster, 64, None), 300);
        let shards = cluster.shard_mailboxes();
        rpc(&shards[1], |reply| ShardRequest::CommitStaged { reply })
            .unwrap()
            .unwrap();
        // The range delete is durable (compact: false), and the kill
        // lands while the post-delete compaction is staging its
        // checkpoint file.
        let del = rpc(&shards[0], |reply| ShardRequest::DeleteChunk {
            range: CHUNK0,
            compact: false,
            reply,
        })
        .unwrap()
        .unwrap();
        assert_eq!(del.removed, 300);
        cluster.shutdown();
    }
    std::fs::write(
        Path::new(&roots[0]).join("store.ckpt.tmp"),
        b"HPCCKPT3\x00partial compaction garbage from a dying writer",
    )
    .unwrap();
    {
        let cluster = mig_cluster(&roots);
        let client = cluster.client();
        assert_eq!(client.count_documents(Filter::True).unwrap(), 300);
        assert_eq!(cluster.stats().per_shard_docs, vec![0, 300]);
        assert!(
            !Path::new(&roots[0]).join("store.ckpt.tmp").exists(),
            "recovery must discard the partial compaction staging file"
        );
        cluster.shutdown();
    }
}

// --- MVCC snapshot kill windows (ARCHITECTURE.md §9.4) ---------------
//
// Epochs, snapshot pins, and the reclaim garbage list are memory-only:
// a kill anywhere in the snapshot lifecycle must leave recovery exactly
// where the journal/checkpoint state machine puts it, with every
// reader-side structure forgotten.

#[test]
fn kill_during_reclaim_under_open_snapshot_replays_to_last_commit() {
    use hpcstore::mongo::storage::RecordId;

    let opts = manual(4);
    let dir = LocalDir::temp("cm-mvcc-reclaim").unwrap();
    let root = dir.describe();
    let survivors: u64;
    {
        let mut eng = Engine::open_with(Box::new(dir), opts.clone()).unwrap();
        eng.create_collection("metrics");
        let rids: Vec<RecordId> = eng.insert_many("metrics", &batch(0, 40)).unwrap();
        eng.sync().unwrap();
        eng.checkpoint().unwrap();
        eng.insert_many("metrics", &batch(40, 20)).unwrap();
        eng.sync().unwrap();

        // A reader pins the 60-doc epoch, then the writer removes a
        // synced range and reclaims. The pin holds the floor back, so
        // the removed versions stay resident (IS1)...
        let reader = eng.reader();
        let snap = reader.snapshot();
        for rid in rids.iter().take(10) {
            eng.remove("metrics", *rid).unwrap();
        }
        eng.sync().unwrap();
        survivors = eng.stats("metrics").docs;
        let freed = eng.reclaim();
        assert_eq!(freed, 0, "open snapshot must hold the reclaim floor");
        assert!(eng.garbage_len() > 0, "the removed versions are pending reclaim");
        {
            let view = reader.view(&snap).unwrap();
            assert_eq!(view.doc_count("metrics"), 60, "pinned epoch still sees 60");
        }
        // ... and the kill lands here: snapshot open, garbage queued,
        // reclaim incomplete. Drop without checkpoint = kill.
    }
    let mut eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
    assert_eq!(
        eng.stats("metrics").docs,
        survivors,
        "recovery must land on the last durable commit (the removes were synced)"
    );
    // All MVCC state died with the process: no pins survive a restart,
    // nothing is left to reclaim, and a fresh snapshot sees the
    // replayed live set.
    assert_eq!(eng.snapshots_open(), 0, "snapshot pins must not survive a kill");
    eng.reclaim();
    assert_eq!(eng.garbage_len(), 0, "a recovered store starts garbage-free");
    let reader = eng.reader();
    let snap = reader.snapshot();
    let view = reader.view(&snap).unwrap();
    assert_eq!(view.doc_count("metrics"), survivors);
}

#[test]
fn kill_mid_getmore_under_open_snapshot_drops_reader_state() {
    use std::sync::{mpsc, Arc};

    use hpcstore::mongo::query::FindOptions;
    use hpcstore::mongo::server::{ReadContext, ReadRequest};

    let opts = manual(4);
    let dir = LocalDir::temp("cm-mvcc-getmore").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open_with(Box::new(dir), opts.clone()).unwrap();
        eng.create_collection("metrics");
        eng.insert_many("metrics", &batch(0, 30)).unwrap();
        eng.sync().unwrap();

        // A cursor is mid-drain: find + one getMore served, the rest
        // unfetched, its snapshot pinned in the read context.
        let ctx = Arc::new(ReadContext::new(
            eng.reader(),
            Kernels::fallback(),
            Registry::new(),
            8,
        ));
        let (tx, rx) = mpsc::channel();
        ctx.serve(ReadRequest::Find {
            filter: Filter::True,
            opts: FindOptions::default().batch_size(8),
            reply: tx,
        });
        let first = rx.recv().unwrap().unwrap();
        let cursor = first.cursor.expect("30 docs at batch 8 leaves a cursor");
        let (tx, rx) = mpsc::channel();
        ctx.serve(ReadRequest::GetMore { cursor, reply: tx });
        rx.recv().unwrap().unwrap();
        assert_eq!(ctx.open_cursors(), 1);
        assert_eq!(eng.snapshots_open(), 1);

        // The writer commits past the pinned epoch, then the kill
        // lands before the next getMore: engine and reader state die
        // together (ctx is dropped with the shard).
        eng.insert_many("metrics", &batch(30, 10)).unwrap();
        eng.sync().unwrap();
    }
    let mut eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
    assert_eq!(
        eng.stats("metrics").docs,
        40,
        "recovery replays every synced commit, including those past the pinned epoch"
    );
    assert_eq!(eng.snapshots_open(), 0, "cursor pins must not survive a kill");
    eng.reclaim();
    assert_eq!(eng.garbage_len(), 0);

    // A fresh read context over the recovered store serves the same
    // query from scratch — the dead cursor is gone, not resumable.
    let ctx = Arc::new(ReadContext::new(
        eng.reader(),
        Kernels::fallback(),
        Registry::new(),
        64,
    ));
    assert_eq!(ctx.open_cursors(), 0, "reader state starts empty after recovery");
    let (tx, rx) = mpsc::channel();
    ctx.serve(ReadRequest::Count { filter: Filter::True, reply: tx });
    assert_eq!(rx.recv().unwrap().unwrap().n, 40);
}

// --- CRUD journal ops kill windows (OP_UPDATE_MANY / OP_DELETE_MANY) --
//
// The full write path journals one frame per batch: an update frame
// carries `old_rid → new doc bytes` pairs, a delete frame carries rids
// only. The two windows that matter: a kill *after* the sync must
// replay the frame exactly once (no lost update, no double delete); a
// kill *before* the sync must leave the pre-mutation state — frames
// are atomic, never partial.

// lint: journal-op(OP_UPDATE_MANY) — the synced batch below is one
// update frame (kill old rid + insert new version per record); the kill
// lands before any checkpoint covers it, so recovery must replay each
// pair exactly once.
#[test]
fn kill_after_synced_update_replays_the_update_frame_exactly_once() {
    use hpcstore::mongo::bson::Value;
    use hpcstore::mongo::storage::RecordId;

    let opts = manual(4);
    let dir = LocalDir::temp("cm-upd").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open_with(Box::new(dir), opts.clone()).unwrap();
        eng.create_collection("metrics");
        let rids: Vec<RecordId> = eng.insert_many("metrics", &batch(0, 30)).unwrap();
        eng.sync().unwrap();
        eng.checkpoint().unwrap(); // gen 1: the update frame is the only tail
        let updates: Vec<(RecordId, Document)> = rids
            .iter()
            .take(10)
            .enumerate()
            .map(|(i, &rid)| (rid, doc(i as u64).set("rev", 1i64)))
            .collect();
        eng.update_many("metrics", &updates).unwrap();
        eng.sync().unwrap();
        // Kill: the frame is durable, nothing covers it yet.
    }
    let eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
    assert_eq!(eng.stats("metrics").docs, 30, "updates are count-neutral");
    assert_eq!(
        eng.recovery_report().frames_replayed,
        1,
        "exactly the one update frame"
    );
    // Every kill+insert pair applied once: 10 documents carry the new
    // version, the other 20 the old, and none twice.
    let reader = eng.reader();
    let snap = reader.snapshot();
    let view = reader.view(&snap).unwrap();
    let mut seen = 0u64;
    let mut updated = 0u64;
    for (_rid, bytes) in view.scan_raw_from("metrics", None) {
        let d = Document::decode(bytes).unwrap();
        seen += 1;
        if d.get("rev").and_then(Value::as_i64) == Some(1) {
            updated += 1;
        }
    }
    assert_eq!(seen, 30);
    assert_eq!(updated, 10, "replayed update frame must hit each target once");
}

// lint: journal-op(OP_DELETE_MANY) — the synced rid-only batch below is
// one delete frame; replaying it twice would remove documents that were
// never targeted, replaying it zero times would resurrect the victims.
#[test]
fn kill_after_synced_delete_replays_the_delete_frame_exactly_once() {
    use hpcstore::mongo::bson::Value;
    use hpcstore::mongo::storage::RecordId;

    let opts = manual(4);
    let dir = LocalDir::temp("cm-del").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open_with(Box::new(dir), opts.clone()).unwrap();
        eng.create_collection("metrics");
        let rids: Vec<RecordId> = eng.insert_many("metrics", &batch(0, 40)).unwrap();
        eng.sync().unwrap();
        eng.checkpoint().unwrap();
        // Every third document: 14 victims of 40.
        let victims: Vec<RecordId> = rids.iter().copied().step_by(3).collect();
        let removed = eng.delete_many("metrics", &victims).unwrap();
        assert_eq!(removed.len(), victims.len());
        eng.sync().unwrap();
        // Kill: the delete frame is durable, the checkpoint predates it.
    }
    let eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
    assert_eq!(eng.stats("metrics").docs, 26);
    assert_eq!(eng.recovery_report().frames_replayed, 1);
    // The surviving ts set is exactly the complement of the victims.
    let reader = eng.reader();
    let snap = reader.snapshot();
    let view = reader.view(&snap).unwrap();
    let mut ts: Vec<i64> = view
        .scan_raw_from("metrics", None)
        .map(|(_rid, bytes)| {
            Document::decode(bytes).unwrap().get("ts").and_then(Value::as_i64).unwrap()
        })
        .collect();
    ts.sort_unstable();
    let expect: Vec<i64> = (0..40i64).filter(|t| t % 3 != 0).collect();
    assert_eq!(ts, expect, "replayed delete frame must remove exactly the victims");
}

#[test]
fn unsynced_update_and_delete_frames_vanish_at_the_kill() {
    use hpcstore::mongo::storage::RecordId;

    let opts = manual(4);
    let dir = LocalDir::temp("cm-crud-unsynced").unwrap();
    let root = dir.describe();
    {
        let mut eng = Engine::open_with(Box::new(dir), opts.clone()).unwrap();
        eng.create_collection("metrics");
        let rids: Vec<RecordId> = eng.insert_many("metrics", &batch(0, 20)).unwrap();
        eng.sync().unwrap();
        eng.checkpoint().unwrap();
        let updates: Vec<(RecordId, Document)> =
            vec![(rids[0], doc(0).set("rev", 7i64))];
        eng.update_many("metrics", &updates).unwrap();
        eng.delete_many("metrics", &rids[5..10]).unwrap();
        // Kill before the sync: both frames were buffered only.
    }
    let eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
    assert_eq!(eng.stats("metrics").docs, 20, "unsynced CRUD frames must vanish");
    assert_eq!(eng.recovery_report().frames_replayed, 0);
}
