//! WiredTiger-like storage engine: record store + write-ahead journal +
//! checkpoints, multiplexing any number of collections over one
//! [`StorageDir`].
//!
//! Write path: encode document → append journal record (durable at the
//! next group-commit `sync`) → insert into the in-memory record store →
//! update secondary indexes. `checkpoint()` snapshots all collections
//! (optionally deflate-compressed) and truncates the journal; `open()`
//! recovers checkpoint + journal replay, so a shard restarted by a later
//! batch job resumes from its Lustre directory — the paper's central
//! persistence story.
//!
//! Journal record: `u32 len | u8 op | u8 coll_len | coll | payload`,
//! op 1 = insert(doc bytes), op 2 = remove(rid u64 + doc bytes for index
//! maintenance).

use std::collections::{BTreeMap, HashMap};
use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use super::index::{Index, IndexSpec};
use super::io::{StorageDir, StorageFile};
use crate::mongo::bson::Document;

/// Record identifier within a collection.
pub type RecordId = u64;

const JOURNAL: &str = "journal.wal";
const OP_INSERT: u8 = 1;
const OP_REMOVE: u8 = 2;
const CKPT_MAGIC: &[u8; 8] = b"HPCCKPT1";

/// Per-collection statistics.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CollectionStats {
    pub docs: u64,
    pub bytes: u64,
    pub index_entries: u64,
}

struct Collection {
    records: BTreeMap<RecordId, Vec<u8>>,
    next_rid: RecordId,
    indexes: Vec<Index>,
    bytes: u64,
}

impl Collection {
    fn new() -> Self {
        Self { records: BTreeMap::new(), next_rid: 0, indexes: Vec::new(), bytes: 0 }
    }

    fn insert_decoded(&mut self, doc: &Document, encoded: Vec<u8>) -> RecordId {
        let rid = self.next_rid;
        self.next_rid += 1;
        self.bytes += encoded.len() as u64;
        self.records.insert(rid, encoded);
        for idx in &mut self.indexes {
            idx.insert(doc, rid);
        }
        rid
    }

    fn remove(&mut self, rid: RecordId) -> Result<Document> {
        let bytes = self
            .records
            .remove(&rid)
            .ok_or_else(|| anyhow::anyhow!("no record {rid}"))?;
        self.bytes -= bytes.len() as u64;
        let doc = Document::decode(&bytes)?;
        for idx in &mut self.indexes {
            idx.remove(&doc, rid);
        }
        Ok(doc)
    }
}

/// The storage engine. Single-threaded by design: each shard server
/// thread owns one engine (WiredTiger-style, one cache per `mongod`).
pub struct Engine {
    dir: Box<dyn StorageDir>,
    journal: Option<Box<dyn StorageFile>>,
    collections: HashMap<String, Collection>,
    journal_enabled: bool,
    compress_checkpoints: bool,
    journal_buf: Vec<u8>,
}

impl Engine {
    /// Open (or create) an engine on `dir`, recovering any checkpoint +
    /// journal found there.
    pub fn open(
        dir: Box<dyn StorageDir>,
        journal_enabled: bool,
        compress_checkpoints: bool,
    ) -> Result<Self> {
        let mut eng = Self {
            journal: None,
            dir,
            collections: HashMap::new(),
            journal_enabled,
            compress_checkpoints,
            journal_buf: Vec::new(),
        };
        eng.recover()?;
        if journal_enabled {
            eng.journal = Some(eng.dir.append_to(JOURNAL)?);
        }
        Ok(eng)
    }

    /// Create a collection if missing.
    pub fn create_collection(&mut self, name: &str) {
        self.collections.entry(name.to_string()).or_insert_with(Collection::new);
    }

    pub fn create_index(&mut self, coll: &str, spec: IndexSpec) -> Result<()> {
        self.create_collection(coll);
        let c = self.collections.get_mut(coll).unwrap();
        if c.indexes.iter().any(|i| i.spec == spec) {
            return Ok(());
        }
        let mut idx = Index::new(spec);
        // Backfill from existing records.
        for (rid, bytes) in &c.records {
            idx.insert(&Document::decode(bytes)?, *rid);
        }
        c.indexes.push(idx);
        Ok(())
    }

    /// Insert one document. Durable after the next [`Self::sync`].
    pub fn insert(&mut self, coll: &str, doc: &Document) -> Result<RecordId> {
        let encoded = doc.encode();
        if self.journal_enabled {
            Self::journal_record(&mut self.journal_buf, OP_INSERT, coll, &encoded);
        }
        let c = self
            .collections
            .get_mut(coll)
            .ok_or_else(|| anyhow::anyhow!("no collection `{coll}`"))?;
        Ok(c.insert_decoded(doc, encoded))
    }

    /// Remove a record (chunk migration source side).
    pub fn remove(&mut self, coll: &str, rid: RecordId) -> Result<Document> {
        let c = self
            .collections
            .get_mut(coll)
            .ok_or_else(|| anyhow::anyhow!("no collection `{coll}`"))?;
        let doc = c.remove(rid)?;
        if self.journal_enabled {
            let mut payload = rid.to_le_bytes().to_vec();
            payload.extend_from_slice(&doc.encode());
            Self::journal_record(&mut self.journal_buf, OP_REMOVE, coll, &payload);
        }
        Ok(doc)
    }

    /// Group commit: flush buffered journal records to the directory.
    pub fn sync(&mut self) -> Result<()> {
        if !self.journal_enabled || self.journal_buf.is_empty() {
            return Ok(());
        }
        let j = self.journal.as_mut().expect("journal open");
        j.append(&self.journal_buf)?;
        j.sync()?;
        self.journal_buf.clear();
        Ok(())
    }

    pub fn fetch(&self, coll: &str, rid: RecordId) -> Option<Document> {
        self.collections
            .get(coll)?
            .records
            .get(&rid)
            .map(|b| Document::decode(b).expect("corrupt record"))
    }

    /// Full scan in record-id order.
    pub fn scan<'a>(
        &'a self,
        coll: &str,
    ) -> Box<dyn Iterator<Item = (RecordId, Document)> + 'a> {
        match self.collections.get(coll) {
            Some(c) => Box::new(
                c.records
                    .iter()
                    .map(|(rid, b)| (*rid, Document::decode(b).expect("corrupt record"))),
            ),
            None => Box::new(std::iter::empty()),
        }
    }

    /// Record ids only (migration batching).
    pub fn record_ids(&self, coll: &str) -> Vec<RecordId> {
        self.collections
            .get(coll)
            .map(|c| c.records.keys().copied().collect())
            .unwrap_or_default()
    }

    pub fn index(&self, coll: &str, name: &str) -> Option<&Index> {
        self.collections
            .get(coll)?
            .indexes
            .iter()
            .find(|i| i.spec.name == name)
    }

    pub fn indexes(&self, coll: &str) -> Vec<&IndexSpec> {
        self.collections
            .get(coll)
            .map(|c| c.indexes.iter().map(|i| &i.spec).collect())
            .unwrap_or_default()
    }

    pub fn stats(&self, coll: &str) -> CollectionStats {
        match self.collections.get(coll) {
            Some(c) => CollectionStats {
                docs: c.records.len() as u64,
                bytes: c.bytes,
                index_entries: c.indexes.iter().map(|i| i.entries()).sum(),
            },
            None => CollectionStats::default(),
        }
    }

    pub fn collection_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.collections.keys().cloned().collect();
        names.sort();
        names
    }

    /// Snapshot all collections to a checkpoint file and truncate the
    /// journal.
    ///
    /// Checkpoint layout: magic, u8 compressed, u32 ncolls, then per
    /// collection: u8 name_len, name, u64 next_rid, u32 n_indexes,
    /// per index (u8 len, joined field names), u64 nrecords, then
    /// records (u64 rid, u32 len, bytes). Payload after the flags byte is
    /// deflate-compressed when enabled.
    pub fn checkpoint(&mut self) -> Result<()> {
        let mut body = Vec::new();
        let mut names: Vec<&String> = self.collections.keys().collect();
        names.sort();
        body.extend_from_slice(&(names.len() as u32).to_le_bytes());
        for name in names {
            let c = &self.collections[name];
            body.push(name.len() as u8);
            body.extend_from_slice(name.as_bytes());
            body.extend_from_slice(&c.next_rid.to_le_bytes());
            body.extend_from_slice(&(c.indexes.len() as u32).to_le_bytes());
            for idx in &c.indexes {
                let joined = idx.spec.fields.join(",");
                body.push(joined.len() as u8);
                body.extend_from_slice(joined.as_bytes());
            }
            body.extend_from_slice(&(c.records.len() as u64).to_le_bytes());
            for (rid, bytes) in &c.records {
                body.extend_from_slice(&rid.to_le_bytes());
                body.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
                body.extend_from_slice(bytes);
            }
        }
        let mut out = CKPT_MAGIC.to_vec();
        if self.compress_checkpoints {
            out.push(1);
            let mut enc =
                flate2::write::DeflateEncoder::new(Vec::new(), flate2::Compression::fast());
            enc.write_all(&body)?;
            out.extend_from_slice(&enc.finish()?);
        } else {
            out.push(0);
            out.extend_from_slice(&body);
        }
        self.dir.write_atomic("store.ckpt", &out)?;
        // Truncate the journal: everything is in the checkpoint now.
        if self.journal_enabled {
            self.journal_buf.clear();
            self.journal = Some(self.dir.create(JOURNAL)?);
        }
        Ok(())
    }

    fn recover(&mut self) -> Result<()> {
        if self.dir.exists("store.ckpt") {
            let raw = self.dir.read("store.ckpt")?;
            self.load_checkpoint(&raw)
                .with_context(|| format!("corrupt checkpoint in {}", self.dir.describe()))?;
        }
        if self.dir.exists(JOURNAL) {
            let raw = self.dir.read(JOURNAL)?;
            self.replay_journal(&raw)
                .with_context(|| format!("corrupt journal in {}", self.dir.describe()))?;
        }
        Ok(())
    }

    fn load_checkpoint(&mut self, raw: &[u8]) -> Result<()> {
        if raw.len() < 9 || &raw[..8] != CKPT_MAGIC {
            bail!("bad checkpoint magic");
        }
        let body: Vec<u8> = if raw[8] == 1 {
            let mut dec = flate2::read::DeflateDecoder::new(&raw[9..]);
            let mut b = Vec::new();
            dec.read_to_end(&mut b)?;
            b
        } else {
            raw[9..].to_vec()
        };
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > body.len() {
                bail!("truncated checkpoint");
            }
            let s = &body[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let ncolls = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        for _ in 0..ncolls {
            let name_len = take(&mut pos, 1)?[0] as usize;
            let name = std::str::from_utf8(take(&mut pos, name_len)?)?.to_string();
            let next_rid = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?);
            let n_idx = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
            let mut specs = Vec::new();
            for _ in 0..n_idx {
                let len = take(&mut pos, 1)?[0] as usize;
                let joined = std::str::from_utf8(take(&mut pos, len)?)?;
                let fields: Vec<&str> = joined.split(',').collect();
                specs.push(IndexSpec::compound(&fields));
            }
            let nrec = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?);
            let mut c = Collection::new();
            for spec in specs {
                c.indexes.push(Index::new(spec));
            }
            for _ in 0..nrec {
                let rid = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?);
                let len = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
                let bytes = take(&mut pos, len)?.to_vec();
                let doc = Document::decode(&bytes)?;
                c.bytes += bytes.len() as u64;
                c.records.insert(rid, bytes);
                for idx in &mut c.indexes {
                    idx.insert(&doc, rid);
                }
            }
            c.next_rid = next_rid;
            self.collections.insert(name, c);
        }
        Ok(())
    }

    fn replay_journal(&mut self, raw: &[u8]) -> Result<()> {
        let mut pos = 0usize;
        while pos + 4 <= raw.len() {
            let len = u32::from_le_bytes(raw[pos..pos + 4].try_into()?) as usize;
            pos += 4;
            if pos + len > raw.len() {
                // Torn tail write — stop at the last complete record.
                log::warn!("journal tail truncated at byte {pos}; dropping partial record");
                break;
            }
            let rec = &raw[pos..pos + len];
            pos += len;
            let op = rec[0];
            let coll_len = rec[1] as usize;
            let coll = std::str::from_utf8(&rec[2..2 + coll_len])?.to_string();
            let payload = &rec[2 + coll_len..];
            self.create_collection(&coll);
            let c = self.collections.get_mut(&coll).unwrap();
            match op {
                OP_INSERT => {
                    let doc = Document::decode(payload)?;
                    c.insert_decoded(&doc, payload.to_vec());
                }
                OP_REMOVE => {
                    let rid = u64::from_le_bytes(payload[..8].try_into()?);
                    let _ = c.remove(rid);
                }
                _ => bail!("unknown journal op {op}"),
            }
        }
        Ok(())
    }

    fn journal_record(buf: &mut Vec<u8>, op: u8, coll: &str, payload: &[u8]) {
        let len = 2 + coll.len() + payload.len();
        buf.extend_from_slice(&(len as u32).to_le_bytes());
        buf.push(op);
        buf.push(coll.len() as u8);
        buf.extend_from_slice(coll.as_bytes());
        buf.extend_from_slice(payload);
    }

    /// Bytes of journal waiting for the next group commit (tests/metrics).
    pub fn pending_journal_bytes(&self) -> usize {
        self.journal_buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mongo::bson::Value;
    use crate::mongo::storage::io::LocalDir;

    fn doc(ts: i64, node: i64) -> Document {
        Document::new().set("ts", ts).set("node_id", node).set("m0", ts as f64 * 0.5)
    }

    fn temp_engine(label: &str, journal: bool, compress: bool) -> (Engine, String) {
        let dir = LocalDir::temp(label).unwrap();
        let path = dir.describe();
        let eng = Engine::open(Box::new(dir), journal, compress).unwrap();
        (eng, path)
    }

    #[test]
    fn insert_fetch_scan() {
        let (mut eng, _) = temp_engine("eng1", true, false);
        eng.create_collection("metrics");
        let r0 = eng.insert("metrics", &doc(1, 10)).unwrap();
        let r1 = eng.insert("metrics", &doc(2, 20)).unwrap();
        assert_ne!(r0, r1);
        assert_eq!(eng.fetch("metrics", r0).unwrap().get_i64("node_id"), Some(10));
        assert_eq!(eng.scan("metrics").count(), 2);
        let s = eng.stats("metrics");
        assert_eq!(s.docs, 2);
        assert!(s.bytes > 0);
    }

    #[test]
    fn indexes_maintained_on_insert_and_remove() {
        let (mut eng, _) = temp_engine("eng2", false, false);
        eng.create_collection("metrics");
        eng.create_index("metrics", IndexSpec::single("node_id")).unwrap();
        let r0 = eng.insert("metrics", &doc(1, 7)).unwrap();
        eng.insert("metrics", &doc(2, 7)).unwrap();
        let idx = eng.index("metrics", "node_id_1").unwrap();
        assert_eq!(idx.point(&[&Value::Int(7)]).len(), 2);
        eng.remove("metrics", r0).unwrap();
        let idx = eng.index("metrics", "node_id_1").unwrap();
        assert_eq!(idx.point(&[&Value::Int(7)]).len(), 1);
    }

    #[test]
    fn index_backfills_existing_records() {
        let (mut eng, _) = temp_engine("eng3", false, false);
        eng.create_collection("metrics");
        for t in 0..20 {
            eng.insert("metrics", &doc(t, t % 4)).unwrap();
        }
        eng.create_index("metrics", IndexSpec::single("ts")).unwrap();
        let idx = eng.index("metrics", "ts_1").unwrap();
        assert_eq!(idx.range(Some(&Value::Int(5)), Some(&Value::Int(15))).len(), 10);
    }

    #[test]
    fn journal_recovery_after_crash() {
        let dir = LocalDir::temp("eng4").unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("metrics");
            for t in 0..10 {
                eng.insert("metrics", &doc(t, 1)).unwrap();
            }
            eng.sync().unwrap();
            // Drop without checkpoint = crash.
        }
        let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("metrics").docs, 10);
        assert_eq!(eng.fetch("metrics", 3).unwrap().get_i64("ts"), Some(3));
    }

    #[test]
    fn unsynced_writes_are_lost_on_crash() {
        let dir = LocalDir::temp("eng5").unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("metrics");
            eng.insert("metrics", &doc(1, 1)).unwrap();
            eng.sync().unwrap();
            eng.insert("metrics", &doc(2, 2)).unwrap();
            // no sync — buffered record lost
            assert!(eng.pending_journal_bytes() > 0);
        }
        let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("metrics").docs, 1);
    }

    #[test]
    fn checkpoint_then_recover_without_journal_replay() {
        for compress in [false, true] {
            let dir = LocalDir::temp("eng6").unwrap();
            let root = dir.describe();
            {
                let mut eng = Engine::open(Box::new(dir), true, compress).unwrap();
                eng.create_collection("metrics");
                eng.create_index("metrics", IndexSpec::single("node_id")).unwrap();
                for t in 0..25 {
                    eng.insert("metrics", &doc(t, t % 3)).unwrap();
                }
                eng.sync().unwrap();
                eng.checkpoint().unwrap();
                // Post-checkpoint writes land in the fresh journal.
                eng.insert("metrics", &doc(100, 9)).unwrap();
                eng.sync().unwrap();
            }
            let eng =
                Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, compress).unwrap();
            assert_eq!(eng.stats("metrics").docs, 26, "compress={compress}");
            // Indexes rebuilt from checkpoint specs + journal replay.
            let idx = eng.index("metrics", "node_id_1").unwrap();
            assert_eq!(idx.point(&[&Value::Int(9)]).len(), 1);
        }
    }

    #[test]
    fn remove_journaled_and_replayed() {
        let dir = LocalDir::temp("eng7").unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("m");
            let r = eng.insert("m", &doc(1, 1)).unwrap();
            eng.insert("m", &doc(2, 2)).unwrap();
            eng.remove("m", r).unwrap();
            eng.sync().unwrap();
        }
        let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("m").docs, 1);
        assert!(eng.fetch("m", 0).is_none());
    }

    #[test]
    fn torn_journal_tail_is_tolerated() {
        let dir = LocalDir::temp("eng8").unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("m");
            eng.insert("m", &doc(1, 1)).unwrap();
            eng.sync().unwrap();
        }
        // Append a torn record: length prefix promising more bytes.
        {
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new()
                .append(true)
                .open(std::path::Path::new(&root).join("journal.wal"))
                .unwrap();
            f.write_all(&100u32.to_le_bytes()).unwrap();
            f.write_all(&[1, 1, b'm']).unwrap(); // incomplete
        }
        let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("m").docs, 1);
    }

    #[test]
    fn journaling_disabled_skips_wal() {
        let (mut eng, root) = temp_engine("eng9", false, false);
        eng.create_collection("m");
        eng.insert("m", &doc(1, 1)).unwrap();
        eng.sync().unwrap();
        assert_eq!(eng.pending_journal_bytes(), 0);
        assert!(!std::path::Path::new(&root).join("journal.wal").exists());
    }
}
