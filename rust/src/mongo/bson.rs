//! BSON-lite: the document model and its binary encoding.
//!
//! Documents are ordered field lists (like BSON); values cover what the
//! OVIS workload and the query engine need: null, bool, i64, f64,
//! string, array, nested document. The binary form is a compact
//! tag-prefixed encoding with explicit lengths, cheap to skip-scan.
//!
//! Wire format (little-endian):
//! ```text
//! doc    := u16 field_count, field*
//! field  := u8 name_len, name bytes, value
//! value  := tag u8, payload
//!   0 null | 1 bool(u8) | 2 i64 | 3 f64 | 4 str(u32 len, bytes)
//!   5 array(u16 count, value*) | 6 doc
//! ```

use anyhow::{bail, Result};

/// A field value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Int(i64),
    F64(f64),
    Str(String),
    Array(Vec<Value>),
    Doc(Document),
}

impl Value {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::F64(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Total ordering for index keys and comparisons: type class first
    /// (null < numbers < strings < arrays < docs), numeric classes
    /// compare by value across Int/F64.
    pub fn type_rank(&self) -> u8 {
        match self {
            Value::Null => 0,
            Value::Bool(_) => 1,
            Value::Int(_) | Value::F64(_) => 2,
            Value::Str(_) => 3,
            Value::Array(_) => 4,
            Value::Doc(_) => 5,
        }
    }

    /// Compare two values under the total order. `None` only for NaN.
    pub fn cmp_total(&self, other: &Value) -> std::cmp::Ordering {
        use std::cmp::Ordering::*;
        let (ra, rb) = (self.type_rank(), other.type_rank());
        if ra != rb {
            return ra.cmp(&rb);
        }
        match (self, other) {
            (Value::Null, Value::Null) => Equal,
            (Value::Bool(a), Value::Bool(b)) => a.cmp(b),
            (a, b) if ra == 2 => {
                let (x, y) = (a.as_f64().unwrap(), b.as_f64().unwrap());
                x.partial_cmp(&y).unwrap_or(Equal)
            }
            (Value::Str(a), Value::Str(b)) => a.cmp(b),
            (Value::Array(a), Value::Array(b)) => {
                for (x, y) in a.iter().zip(b) {
                    let o = x.cmp_total(y);
                    if o != Equal {
                        return o;
                    }
                }
                a.len().cmp(&b.len())
            }
            (Value::Doc(a), Value::Doc(b)) => {
                for ((ka, va), (kb, vb)) in a.fields.iter().zip(&b.fields) {
                    let o = ka.cmp(kb).then_with(|| va.cmp_total(vb));
                    if o != Equal {
                        return o;
                    }
                }
                a.fields.len().cmp(&b.fields.len())
            }
            _ => Equal,
        }
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Int(v)
    }
}

impl From<u32> for Value {
    fn from(v: u32) -> Self {
        Value::Int(v as i64)
    }
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::F64(v)
    }
}

impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}

impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}

/// An ordered document.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Document {
    pub fields: Vec<(String, Value)>,
}

impl Document {
    pub fn new() -> Self {
        Self::default()
    }

    /// Builder-style append (replaces an existing field of that name).
    pub fn set(mut self, name: &str, value: impl Into<Value>) -> Self {
        self.put(name, value);
        self
    }

    pub fn put(&mut self, name: &str, value: impl Into<Value>) {
        let value = value.into();
        for (k, v) in self.fields.iter_mut() {
            if k == name {
                *v = value;
                return;
            }
        }
        self.fields.push((name.to_string(), value));
    }

    pub fn get(&self, name: &str) -> Option<&Value> {
        self.fields.iter().find(|(k, _)| k == name).map(|(_, v)| v)
    }

    pub fn get_i64(&self, name: &str) -> Option<i64> {
        self.get(name).and_then(Value::as_i64)
    }

    pub fn get_f64(&self, name: &str) -> Option<f64> {
        self.get(name).and_then(Value::as_f64)
    }

    pub fn len(&self) -> usize {
        self.fields.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fields.is_empty()
    }

    /// Project onto the named fields (keeping document order). Generic
    /// over the name type so hot call sites can pass `&["ts", ..]`
    /// without allocating a `Vec<String>` per projection.
    pub fn project<S: AsRef<str>>(&self, names: &[S]) -> Document {
        Document {
            fields: self
                .fields
                .iter()
                .filter(|(k, _)| names.iter().any(|n| n.as_ref() == k))
                .cloned()
                .collect(),
        }
    }

    /// Encode to the binary wire form.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_len());
        self.encode_into(&mut out);
        out
    }

    pub fn encode_into(&self, out: &mut Vec<u8>) {
        assert!(self.fields.len() <= u16::MAX as usize, "too many fields");
        out.extend_from_slice(&(self.fields.len() as u16).to_le_bytes());
        for (name, value) in &self.fields {
            assert!(name.len() <= u8::MAX as usize, "field name too long");
            out.push(name.len() as u8);
            out.extend_from_slice(name.as_bytes());
            encode_value(value, out);
        }
    }

    /// Exact size of [`Self::encode`] output (used for wire accounting
    /// without encoding).
    pub fn encoded_len(&self) -> usize {
        2 + self
            .fields
            .iter()
            .map(|(n, v)| 1 + n.len() + value_len(v))
            .sum::<usize>()
    }

    pub fn decode(bytes: &[u8]) -> Result<Document> {
        let mut cur = Cursor { bytes, pos: 0 };
        let doc = decode_doc(&mut cur)?;
        if cur.pos != bytes.len() {
            bail!("trailing bytes after document");
        }
        Ok(doc)
    }
}

fn value_len(v: &Value) -> usize {
    1 + match v {
        Value::Null => 0,
        Value::Bool(_) => 1,
        Value::Int(_) => 8,
        Value::F64(_) => 8,
        Value::Str(s) => 4 + s.len(),
        Value::Array(items) => 2 + items.iter().map(value_len).sum::<usize>(),
        Value::Doc(d) => d.encoded_len(),
    }
}

fn encode_value(v: &Value, out: &mut Vec<u8>) {
    match v {
        Value::Null => out.push(0),
        Value::Bool(b) => {
            out.push(1);
            out.push(*b as u8);
        }
        Value::Int(i) => {
            out.push(2);
            out.extend_from_slice(&i.to_le_bytes());
        }
        Value::F64(f) => {
            out.push(3);
            out.extend_from_slice(&f.to_le_bytes());
        }
        Value::Str(s) => {
            out.push(4);
            out.extend_from_slice(&(s.len() as u32).to_le_bytes());
            out.extend_from_slice(s.as_bytes());
        }
        Value::Array(items) => {
            out.push(5);
            assert!(items.len() <= u16::MAX as usize);
            out.extend_from_slice(&(items.len() as u16).to_le_bytes());
            for item in items {
                encode_value(item, out);
            }
        }
        Value::Doc(d) => {
            out.push(6);
            d.encode_into(out);
        }
    }
}

/// A zero-copy view over one *encoded* document.
///
/// The read path stores records as encoded bytes; most of them are only
/// ever probed for a field or two (`ts`, `node_id`) by the matcher or
/// the kernel column extraction. `RawDoc` seeks a named field by
/// skip-scanning the tag-prefixed encoding — no allocation, no
/// materialized [`Document`] — and decodes a value lazily only when the
/// caller actually looks at it.
///
/// Invariants (documented in docs/ARCHITECTURE.md §7):
/// * The view never panics on malformed bytes: a seek over bytes not
///   produced by [`Document::encode`] simply yields `None` (the engine
///   only stores encoder output, so this is belt-and-braces).
/// * `get` returns the *first* field of that name, matching
///   [`Document::get`] (the encoder never emits duplicates).
/// * `decode`/`project` are the only materialization points; everything
///   else borrows from the underlying buffer.
#[derive(Clone, Copy, Debug)]
pub struct RawDoc<'a> {
    bytes: &'a [u8],
}

impl<'a> RawDoc<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        Self { bytes }
    }

    /// The underlying encoded bytes.
    pub fn bytes(&self) -> &'a [u8] {
        self.bytes
    }

    /// Field count from the header (0 when the header is malformed).
    pub fn field_count(&self) -> usize {
        match self.bytes {
            [a, b, ..] => u16::from_le_bytes([*a, *b]) as usize,
            _ => 0,
        }
    }

    /// Seek `name` and return a lazy view of its value.
    pub fn get(&self, name: &str) -> Option<RawValue<'a>> {
        let b = self.bytes;
        let mut pos = 2usize;
        for _ in 0..self.field_count() {
            let nlen = *b.get(pos)? as usize;
            pos += 1;
            let fname = b.get(pos..pos + nlen)?;
            pos += nlen;
            if fname == name.as_bytes() {
                return raw_value_at(b, pos).map(|(v, _)| v);
            }
            pos = skip_value(b, pos)?;
        }
        None
    }

    pub fn get_i64(&self, name: &str) -> Option<i64> {
        self.get(name)?.as_i64()
    }

    pub fn get_f64(&self, name: &str) -> Option<f64> {
        self.get(name)?.as_f64()
    }

    /// Materialize the full document — the serve path's one decode.
    pub fn decode(&self) -> Result<Document> {
        Document::decode(self.bytes)
    }

    /// Decode only the named fields, in document order: the projection
    /// path materializes exactly what it returns. Malformed bytes yield
    /// the fields decoded so far. Generic over the name type (see
    /// [`Document::project`]) so callers never allocate per projection.
    pub fn project<S: AsRef<str>>(&self, names: &[S]) -> Document {
        let b = self.bytes;
        let mut out = Document::new();
        let mut pos = 2usize;
        for _ in 0..self.field_count() {
            let Some(&nlen) = b.get(pos) else { return out };
            let nlen = nlen as usize;
            pos += 1;
            let Some(fname) = b.get(pos..pos + nlen) else { return out };
            pos += nlen;
            if names.iter().any(|n| n.as_ref().as_bytes() == fname) {
                let Some((v, next)) = raw_value_at(b, pos) else { return out };
                if let (Ok(name), Some(value)) =
                    (std::str::from_utf8(fname), v.to_value())
                {
                    out.put(name, value);
                }
                pos = next;
            } else {
                let Some(next) = skip_value(b, pos) else { return out };
                pos = next;
            }
        }
        out
    }
}

/// A lazily decoded value inside a [`RawDoc`]: scalars are read in
/// place; arrays and nested documents keep their encoded bytes and
/// materialize only if actually compared against a container or
/// projected.
#[derive(Clone, Copy, Debug)]
pub enum RawValue<'a> {
    Null,
    Bool(bool),
    Int(i64),
    F64(f64),
    Str(&'a str),
    /// Encoded array (tag byte included), materialized on demand.
    Array(&'a [u8]),
    /// Encoded nested document (tag byte included), materialized on
    /// demand.
    Doc(&'a [u8]),
}

impl<'a> RawValue<'a> {
    /// Same type classes as [`Value::type_rank`].
    pub fn type_rank(&self) -> u8 {
        match self {
            RawValue::Null => 0,
            RawValue::Bool(_) => 1,
            RawValue::Int(_) | RawValue::F64(_) => 2,
            RawValue::Str(_) => 3,
            RawValue::Array(_) => 4,
            RawValue::Doc(_) => 5,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            RawValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            RawValue::F64(f) => Some(*f),
            RawValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// Materialize into an owned [`Value`] (`None` only for malformed
    /// nested payloads).
    pub fn to_value(&self) -> Option<Value> {
        Some(match self {
            RawValue::Null => Value::Null,
            RawValue::Bool(b) => Value::Bool(*b),
            RawValue::Int(i) => Value::Int(*i),
            RawValue::F64(f) => Value::F64(*f),
            RawValue::Str(s) => Value::Str((*s).to_string()),
            RawValue::Array(bytes) | RawValue::Doc(bytes) => {
                let mut cur = Cursor { bytes: *bytes, pos: 0 };
                let v = decode_value(&mut cur).ok()?;
                if cur.pos != bytes.len() {
                    return None;
                }
                v
            }
        })
    }

    /// [`Value::cmp_total`] with the raw side on the left. Scalars
    /// compare in place; containers materialize only when both sides
    /// are the same type class (cross-class ordering needs no decode).
    pub fn cmp_total(&self, other: &Value) -> std::cmp::Ordering {
        use std::cmp::Ordering::*;
        let (ra, rb) = (self.type_rank(), other.type_rank());
        if ra != rb {
            return ra.cmp(&rb);
        }
        match (self, other) {
            (RawValue::Null, _) => Equal,
            (RawValue::Bool(a), Value::Bool(b)) => a.cmp(b),
            (a, b) if ra == 2 => {
                let (x, y) = (a.as_f64().unwrap(), b.as_f64().unwrap());
                x.partial_cmp(&y).unwrap_or(Equal)
            }
            (RawValue::Str(a), Value::Str(b)) => (*a).cmp(b.as_str()),
            _ => match self.to_value() {
                Some(v) => v.cmp_total(other),
                // Malformed nested payload: mirror cmp_total's NaN
                // posture and treat as Equal within the class.
                None => Equal,
            },
        }
    }
}

/// Lazily view the value whose tag byte sits at `pos`; returns the view
/// and the offset just past the value.
fn raw_value_at(b: &[u8], pos: usize) -> Option<(RawValue<'_>, usize)> {
    let tag = *b.get(pos)?;
    let start = pos;
    let pos = pos + 1;
    Some(match tag {
        0 => (RawValue::Null, pos),
        1 => (RawValue::Bool(*b.get(pos)? != 0), pos + 1),
        2 => (
            RawValue::Int(i64::from_le_bytes(b.get(pos..pos + 8)?.try_into().ok()?)),
            pos + 8,
        ),
        3 => (
            RawValue::F64(f64::from_le_bytes(b.get(pos..pos + 8)?.try_into().ok()?)),
            pos + 8,
        ),
        4 => {
            let len = u32::from_le_bytes(b.get(pos..pos + 4)?.try_into().ok()?) as usize;
            let s = std::str::from_utf8(b.get(pos + 4..pos + 4 + len)?).ok()?;
            (RawValue::Str(s), pos + 4 + len)
        }
        5 => {
            let end = skip_value(b, start)?;
            (RawValue::Array(&b[start..end]), end)
        }
        6 => {
            let end = skip_value(b, start)?;
            (RawValue::Doc(&b[start..end]), end)
        }
        _ => return None,
    })
}

/// Offset just past the value whose tag byte sits at `pos` (`None` on
/// malformed bytes) — the skip half of the skip-scan.
fn skip_value(b: &[u8], pos: usize) -> Option<usize> {
    let tag = *b.get(pos)?;
    let pos = pos + 1;
    Some(match tag {
        0 => pos,
        1 => {
            b.get(pos)?;
            pos + 1
        }
        2 | 3 => {
            b.get(pos..pos + 8)?;
            pos + 8
        }
        4 => {
            let len = u32::from_le_bytes(b.get(pos..pos + 4)?.try_into().ok()?) as usize;
            b.get(pos + 4..pos + 4 + len)?;
            pos + 4 + len
        }
        5 => {
            let count = u16::from_le_bytes(b.get(pos..pos + 2)?.try_into().ok()?) as usize;
            let mut p = pos + 2;
            for _ in 0..count {
                p = skip_value(b, p)?;
            }
            p
        }
        6 => {
            let count = u16::from_le_bytes(b.get(pos..pos + 2)?.try_into().ok()?) as usize;
            let mut p = pos + 2;
            for _ in 0..count {
                let nlen = *b.get(p)? as usize;
                p += 1;
                b.get(p..p + nlen)?;
                p += nlen;
                p = skip_value(b, p)?;
            }
            p
        }
        _ => return None,
    })
}

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.bytes.len() {
            bail!("truncated document (need {n} bytes at {})", self.pos);
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

fn decode_doc(cur: &mut Cursor) -> Result<Document> {
    let count = cur.u16()? as usize;
    let mut fields = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = cur.u8()? as usize;
        let name = std::str::from_utf8(cur.take(name_len)?)?.to_string();
        let value = decode_value(cur)?;
        fields.push((name, value));
    }
    Ok(Document { fields })
}

fn decode_value(cur: &mut Cursor) -> Result<Value> {
    Ok(match cur.u8()? {
        0 => Value::Null,
        1 => Value::Bool(cur.u8()? != 0),
        2 => Value::Int(i64::from_le_bytes(cur.take(8)?.try_into().unwrap())),
        3 => Value::F64(f64::from_le_bytes(cur.take(8)?.try_into().unwrap())),
        4 => {
            let len = cur.u32()? as usize;
            Value::Str(std::str::from_utf8(cur.take(len)?)?.to_string())
        }
        5 => {
            let count = cur.u16()? as usize;
            let mut items = Vec::with_capacity(count);
            for _ in 0..count {
                items.push(decode_value(cur)?);
            }
            Value::Array(items)
        }
        6 => Value::Doc(decode_doc(cur)?),
        t => bail!("unknown value tag {t}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Document {
        Document::new()
            .set("ts", 25_246_080i64)
            .set("node_id", 1234i64)
            .set("cpu_user", 0.37)
            .set("hostname", "nid01234")
            .set("flags", Value::Array(vec![Value::Bool(true), Value::Int(7)]))
            .set(
                "nested",
                Value::Doc(Document::new().set("a", 1i64).set("b", "x")),
            )
            .set("none", Value::Null)
    }

    #[test]
    fn round_trip() {
        let d = sample();
        let bytes = d.encode();
        assert_eq!(bytes.len(), d.encoded_len());
        let d2 = Document::decode(&bytes).unwrap();
        assert_eq!(d, d2);
    }

    #[test]
    fn put_replaces() {
        let mut d = Document::new().set("a", 1i64);
        d.put("a", 2i64);
        assert_eq!(d.len(), 1);
        assert_eq!(d.get_i64("a"), Some(2));
    }

    #[test]
    fn field_order_preserved() {
        let d = Document::new().set("z", 1i64).set("a", 2i64);
        assert_eq!(d.fields[0].0, "z");
        let d2 = Document::decode(&d.encode()).unwrap();
        assert_eq!(d2.fields[0].0, "z");
    }

    #[test]
    fn projection() {
        let d = sample();
        let p = d.project(&["ts", "hostname"]);
        assert_eq!(p.len(), 2);
        assert!(p.get("cpu_user").is_none());
        // Owned names keep working through the generic signature.
        assert_eq!(d.project(&["ts".to_string()]), d.project(&["ts"]));
    }

    #[test]
    fn numeric_cross_type_compare() {
        use std::cmp::Ordering::*;
        assert_eq!(Value::Int(2).cmp_total(&Value::F64(2.0)), Equal);
        assert_eq!(Value::Int(2).cmp_total(&Value::F64(2.5)), Less);
        assert_eq!(Value::F64(3.0).cmp_total(&Value::Int(2)), Greater);
        // Type classes: numbers < strings.
        assert_eq!(Value::Int(999).cmp_total(&Value::Str("a".into())), Less);
        assert_eq!(Value::Null.cmp_total(&Value::Bool(false)), Less);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Document::decode(&[]).is_err());
        assert!(Document::decode(&[1, 0]).is_err()); // count=1, truncated
        let mut ok = sample().encode();
        ok.push(0xFF); // trailing byte
        assert!(Document::decode(&ok).is_err());
        // Unknown tag.
        assert!(Document::decode(&[1, 0, 1, b'a', 99]).is_err());
    }

    #[test]
    fn raw_doc_seeks_fields_without_decoding() {
        let d = sample();
        let enc = d.encode();
        let raw = RawDoc::new(&enc);
        assert_eq!(raw.field_count(), d.len());
        assert_eq!(raw.get_i64("ts"), Some(25_246_080));
        assert_eq!(raw.get_i64("node_id"), Some(1234));
        assert_eq!(raw.get_f64("cpu_user"), Some(0.37));
        assert!(matches!(raw.get("hostname"), Some(RawValue::Str("nid01234"))));
        assert!(matches!(raw.get("none"), Some(RawValue::Null)));
        assert!(raw.get("missing").is_none());
        // Containers materialize lazily and exactly.
        assert_eq!(raw.get("flags").unwrap().to_value().as_ref(), d.get("flags"));
        assert_eq!(raw.get("nested").unwrap().to_value().as_ref(), d.get("nested"));
        // Full decode round-trips.
        assert_eq!(raw.decode().unwrap(), d);
    }

    #[test]
    fn raw_projection_matches_document_projection() {
        let d = sample();
        let enc = d.encode();
        let names = ["ts", "hostname", "nested", "missing"];
        assert_eq!(RawDoc::new(&enc).project(&names), d.project(&names));
        // Empty projection.
        assert_eq!(RawDoc::new(&enc).project::<&str>(&[]), Document::new());
    }

    #[test]
    fn raw_cmp_total_agrees_with_value_cmp_total() {
        use std::cmp::Ordering;
        let d = sample();
        let enc = d.encode();
        let raw = RawDoc::new(&enc);
        for (name, _) in &d.fields {
            let rv = raw.get(name).unwrap();
            let dv = d.get(name).unwrap();
            assert_eq!(rv.type_rank(), dv.type_rank(), "{name}");
            // Against every field value of the same document — covers
            // same-class and cross-class comparisons.
            for (_, other) in &d.fields {
                assert_eq!(rv.cmp_total(other), dv.cmp_total(other), "{name} vs {other:?}");
            }
        }
        // Numeric cross-type through the raw side.
        let n = Document::new().set("x", 2i64).encode();
        let rx = RawDoc::new(&n).get("x").unwrap();
        assert_eq!(rx.cmp_total(&Value::F64(2.0)), Ordering::Equal);
        assert_eq!(rx.cmp_total(&Value::F64(2.5)), Ordering::Less);
        assert_eq!(rx.cmp_total(&Value::Str("a".into())), Ordering::Less);
    }

    #[test]
    fn raw_doc_tolerates_garbage() {
        // Truncated, empty, and corrupt-tag buffers must yield None,
        // never panic.
        for bytes in [
            &[][..],
            &[1][..],
            &[1, 0][..],                 // count=1, no field
            &[1, 0, 3, b'a'][..],        // name overruns
            &[1, 0, 1, b'a', 99][..],    // unknown tag
            &[1, 0, 1, b'a', 2, 1][..],  // i64 payload truncated
        ] {
            let raw = RawDoc::new(bytes);
            assert!(raw.get("a").is_none(), "{bytes:?}");
            assert!(raw.get_i64("a").is_none());
        }
        // A valid prefix followed by a torn second field: the first
        // field still resolves, the torn one does not.
        let mut enc = Document::new().set("a", 7i64).set("b", 8i64).encode();
        enc.truncate(enc.len() - 4);
        let raw = RawDoc::new(&enc);
        assert_eq!(raw.get_i64("a"), Some(7));
        assert!(raw.get("b").is_none());
    }

    #[test]
    fn encoded_len_matches_for_everything() {
        use crate::testing::{check, gens, Gen};
        use crate::util::rng::Pcg32;
        check(
            "encoded-len",
            &(|rng: &mut Pcg32| {
                let mut d = Document::new();
                let n = rng.next_bounded(10);
                for i in 0..n {
                    let v = match rng.next_bounded(5) {
                        0 => Value::Null,
                        1 => Value::Int(rng.next_u64() as i64),
                        2 => Value::F64(rng.next_f64()),
                        3 => Value::Str(gens::ident(12).generate(rng)),
                        _ => Value::Array(vec![Value::Int(1), Value::Null]),
                    };
                    d.put(&format!("f{i}"), v);
                }
                d
            }),
            |d| {
                let bytes = d.encode();
                if bytes.len() != d.encoded_len() {
                    return Err(format!("len {} != {}", bytes.len(), d.encoded_len()));
                }
                let d2 = Document::decode(&bytes).map_err(|e| e.to_string())?;
                if &d2 != d {
                    return Err("round trip mismatch".into());
                }
                Ok(())
            },
        );
    }
}
