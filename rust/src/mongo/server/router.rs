//! Query router (`mongos`): "the only interface to a sharded cluster
//! from the perspective of applications" (paper §3.1).
//!
//! * `insertMany(ordered=false)`: the key columns of the batch go
//!   through the AOT **route kernel** (hash + chunk lookup + per-shard
//!   histogram) — the L1/L2 hot path — and the per-shard sub-batches are
//!   dispatched concurrently. Stale-version and wrong-owner rejects are
//!   re-routed after a map refresh, preserving unordered semantics.
//! * `find`: scatter to every shard (conditional finds don't carry the
//!   full shard key), gather one stream per shard, and serve through a
//!   router-side cursor. Unsorted finds drain the streams in shard
//!   order; sorted finds **k-way merge** the streams on the sort key —
//!   each shard returns its results fully ordered, so taking the best
//!   head across streams yields one *globally* ordered result, not a
//!   per-shard-ordered concatenation.
//!
//! With replica sets (`replicas > 1`) each logical shard is a member
//! list. Writes go to the member the router believes is primary (the
//! `primary_hint`); a `NotPrimary` reject updates the hint from the
//! reply's leader field and retries after a jittered backoff — safe,
//! because a rejected write mutated nothing. A *dead* member is
//! different: a send that never reached a mailbox is retried against
//! the next member (nothing was delivered), but a reply channel that
//! dies **after** the send surfaces as the typed
//! [`WireError::ShardUnavailable`] — the write may or may not have
//! applied, and blind resend could double-apply, so the ambiguity is
//! the client's to resolve (ARCHITECTURE.md §10). Reads carry no such
//! ambiguity and degrade across members per the read preference.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::config::{ReadPreference, ShardKeyKind, WriteConcern};
use crate::mongo::aggregate::{AggPipeline, PartialTable};
use crate::mongo::bson::{Document, Value};
use crate::mongo::query::{Filter, FindOptions, SortDir};
use crate::mongo::sharding::chunk::{ChunkMap, ShardKey};
use crate::mongo::wire::{
    agg_reply_wire_bytes, agg_wire_bytes, batch_wire_bytes, find_wire_bytes, rpc, ConfigRequest,
    DeleteReply, FindReply, Reply, ShardRequest, UpdateReply, WireError,
};
use crate::metrics::{names, Registry};
use crate::runtime::Kernels;
use crate::util::ids::RouterId;
use crate::util::Backoff;

/// Backoff base/cap (µs) for router retry loops: small enough that a
/// one-bounce stale-version retry costs microseconds, capped low
/// enough that an election-length outage is polled a few times per
/// heartbeat interval rather than once.
const BACKOFF_BASE_US: u64 = 200;
const BACKOFF_CAP_US: u64 = 20_000;

/// Result of an `insertMany` through the router.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct InsertManyReply {
    pub inserted: usize,
    /// Documents that needed a second routing pass (stale map and/or
    /// wrong owner after a concurrent split/migration).
    pub rerouted: usize,
}

/// Router statistics snapshot.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RouterStatsReply {
    /// `insertMany` requests served (including buffered flushes).
    pub inserts: u64,
    /// `find`/`count` requests served.
    pub finds: u64,
    /// Chunk-map version this router holds.
    pub map_version: u64,
    /// Estimated bytes this router put on the interconnect.
    pub wire_bytes_out: u64,
}

/// Requests handled by a router.
pub enum RouterRequest {
    InsertMany {
        docs: Vec<Document>,
        reply: Reply<Result<InsertManyReply, WireError>>,
    },
    /// Bulk-ingest leg: documents land in the router's ingest buffer and
    /// are flushed to the shards once `router_flush_docs` accumulate or
    /// the flush deadline passes — group commit across clients. The
    /// reply is sent when the flush containing this batch completes.
    InsertBuffered {
        docs: Vec<Document>,
        reply: Reply<Result<InsertManyReply, WireError>>,
    },
    Find {
        filter: Filter,
        opts: FindOptions,
        reply: Reply<Result<FindReply, WireError>>,
    },
    GetMore {
        cursor: u64,
        reply: Reply<Result<FindReply, WireError>>,
    },
    /// Cluster-wide count: scatter to all shards, sum — retried until
    /// every shard answered under the same chunk-map version, so the
    /// per-shard counts compose exactly even mid-migration.
    Count {
        filter: Filter,
        reply: Reply<Result<u64, WireError>>,
    },
    /// Cluster-wide aggregation: scatter the pipeline to all shards
    /// under the same version-uniform protocol as `Count`, merge the
    /// per-shard partial accumulator tables (or, in full-ship baseline
    /// mode, centrally fold the shipped documents), then apply the
    /// final `$sort`/`$limit` and reply with result documents.
    Aggregate {
        pipeline: AggPipeline,
        reply: Reply<Result<Vec<Document>, WireError>>,
    },
    /// Filter-driven cluster-wide update (`$set`-style top-level field
    /// merge). Targeted to the owner set when the filter pins the shard
    /// key, broadcast otherwise.
    Update {
        filter: Filter,
        set: Document,
        reply: Reply<Result<UpdateReply, WireError>>,
    },
    /// Filter-driven cluster-wide delete.
    Delete {
        filter: Filter,
        reply: Reply<Result<DeleteReply, WireError>>,
    },
    CreateIndex {
        spec: crate::mongo::storage::index::IndexSpec,
        reply: Reply<Result<(), WireError>>,
    },
    Stats {
        reply: Reply<RouterStatsReply>,
    },
    // lint: allow(no_reply, shutdown is fire-and-forget; callers join the
    // server thread instead of waiting on a reply)
    Shutdown,
}

pub type RouterMailbox = mpsc::Sender<RouterRequest>;

/// One shard's slice of a scattered find: the documents buffered from
/// it (in shard-local order — sorted when the query sorts) and its open
/// shard-side cursor, if any.
struct ShardStream {
    shard: usize,
    /// Member the stream was opened on: shard-side cursors live in one
    /// member's reader state, so every GetMore must go back to it.
    member: usize,
    cursor: Option<u64>,
    buf: VecDeque<Document>,
    /// Set when, at scatter time, the router's map said this shard is
    /// the donor of a *published* migration handoff: documents in the
    /// range are orphans (the destination's copy is live) and every
    /// batch this stream pulls — first reply and GetMores alike — is
    /// filtered through it.
    orphan_fence: Option<(ShardKey, (u64, u64))>,
}

struct RouterCursor {
    /// Per-shard result streams; exhausted streams are dropped.
    streams: Vec<ShardStream>,
    /// The query's sort, if any: streams are k-way merged on this key
    /// instead of concatenated, so the client sees one globally ordered
    /// stream across shards.
    sort: Option<(String, SortDir)>,
    remaining: Option<usize>,
    batch: usize,
}

/// Router process state + event loop.
pub struct Router {
    id: RouterId,
    map: ChunkMap,
    /// Per-shard member mailboxes (`members[shard][member]`). An
    /// unreplicated cluster has one member per shard.
    members: Vec<Vec<mpsc::Sender<ShardRequest>>>,
    /// Which member of each shard the router currently believes is
    /// primary. Corrected lazily from `NotPrimary` rejects.
    primary_hint: Vec<usize>,
    config: mpsc::Sender<ConfigRequest>,
    kernels: Kernels,
    metrics: Registry,
    cursors: HashMap<u64, RouterCursor>,
    next_cursor: u64,
    default_batch: usize,
    /// Flush the ingest buffer once it holds this many documents.
    flush_docs: usize,
    /// Flush the ingest buffer at this deadline after its first doc.
    flush_interval: Duration,
    /// Aggregation push-down: when set, shards fold matches into
    /// partial accumulator tables and ship those; when clear, shards
    /// ship every matching document and the router folds centrally
    /// (the bench baseline).
    agg_partial: bool,
    /// Write concern stamped on every shard write; `Majority` holds
    /// the shard's reply until a majority of members durably applied.
    wc: WriteConcern,
    /// Which member reads are routed to (primary vs. a secondary).
    read_pref: ReadPreference,
    /// Deadline for write/scatter retry loops (`StoreConfig::
    /// write_retry_ms`): how long the router keeps retrying
    /// stale-version, migration-blocked, and not-primary rejects
    /// before giving up.
    write_retry_ms: u64,
    /// Buffered-ingest documents awaiting the next flush.
    ingest_buf: Vec<Document>,
    /// Per-contributor (doc count, reply) acks for the buffered docs.
    pending_acks: Vec<(usize, Reply<Result<InsertManyReply, WireError>>)>,
    /// When the oldest buffered document arrived.
    buffered_since: Option<Instant>,
    inserts: u64,
    finds: u64,
    wire_bytes_out: u64,
}

impl Router {
    /// Build a router over the given shard mailboxes. `flush_docs` /
    /// `flush_interval` govern the buffered-ingest group commit.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: RouterId,
        map: ChunkMap,
        members: Vec<Vec<mpsc::Sender<ShardRequest>>>,
        config: mpsc::Sender<ConfigRequest>,
        kernels: Kernels,
        metrics: Registry,
        default_batch: usize,
        flush_docs: usize,
        flush_interval: Duration,
        agg_partial: bool,
        wc: WriteConcern,
        read_pref: ReadPreference,
        write_retry_ms: u64,
    ) -> Self {
        let primary_hint = vec![0; members.len()];
        Self {
            id,
            map,
            members,
            primary_hint,
            config,
            kernels,
            metrics,
            cursors: HashMap::new(),
            next_cursor: 1,
            default_batch,
            flush_docs: flush_docs.max(1),
            flush_interval,
            agg_partial,
            wc,
            read_pref,
            write_retry_ms,
            ingest_buf: Vec::new(),
            pending_acks: Vec::new(),
            buffered_since: None,
            inserts: 0,
            finds: 0,
            wire_bytes_out: 0,
        }
    }

    /// Spawn the event loop thread; returns its mailbox and join handle.
    pub fn spawn(self) -> (RouterMailbox, std::thread::JoinHandle<()>) {
        let (tx, rx) = mpsc::channel();
        let join = self.spawn_with(rx);
        (tx, join)
    }

    /// Spawn on a pre-created channel.
    pub fn spawn_with(mut self, rx: mpsc::Receiver<RouterRequest>) -> std::thread::JoinHandle<()> {
        std::thread::Builder::new()
            .name(format!("{}", self.id))
            .spawn(move || self.run(rx))
            // lint: allow(panic, thread spawn fails only on OS resource
            // exhaustion at cluster startup, before any data is live)
            .expect("spawn router thread")
    }

    fn run(&mut self, rx: mpsc::Receiver<RouterRequest>) {
        loop {
            // With buffered documents pending, wait only until the flush
            // deadline; otherwise block for the next request.
            let req = if self.ingest_buf.is_empty() {
                match rx.recv() {
                    Ok(r) => r,
                    Err(_) => break,
                }
            } else {
                let deadline = self
                    .buffered_since
                    .map(|t| t + self.flush_interval)
                    .unwrap_or_else(Instant::now);
                let now = Instant::now();
                if now >= deadline {
                    self.flush_ingest();
                    continue;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => r,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        self.flush_ingest();
                        continue;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            };
            match req {
                RouterRequest::Shutdown => break,
                RouterRequest::InsertMany { docs, reply } => {
                    // Preserve arrival order with any buffered docs.
                    self.flush_ingest();
                    let t = Instant::now();
                    let r = self.handle_insert_many(docs);
                    self.metrics
                        .observe(names::ROUTER_INSERT_MANY_NS, t.elapsed().as_nanos() as u64);
                    let _ = reply.send(r);
                }
                RouterRequest::InsertBuffered { docs, reply } => {
                    if docs.is_empty() {
                        // Nothing to buffer — ack now, or the reply would
                        // strand (an empty buffer never schedules a flush).
                        let _ = reply.send(Ok(InsertManyReply::default()));
                        continue;
                    }
                    if self.ingest_buf.is_empty() {
                        self.buffered_since = Some(Instant::now());
                    }
                    let n = docs.len();
                    self.ingest_buf.extend(docs);
                    self.pending_acks.push((n, reply));
                    if self.ingest_buf.len() >= self.flush_docs {
                        self.flush_ingest();
                    }
                }
                RouterRequest::Find { filter, opts, reply } => {
                    // Read-your-writes: buffered docs become visible first.
                    self.flush_ingest();
                    let t = Instant::now();
                    let r = self.handle_find(filter, opts);
                    self.metrics
                        .observe(names::ROUTER_FIND_NS, t.elapsed().as_nanos() as u64);
                    let _ = reply.send(r);
                }
                RouterRequest::GetMore { cursor, reply } => {
                    let _ = reply.send(self.handle_get_more(cursor));
                }
                RouterRequest::Count { filter, reply } => {
                    self.flush_ingest();
                    let _ = reply.send(self.handle_count(filter));
                }
                RouterRequest::Aggregate { pipeline, reply } => {
                    // Read-your-writes: buffered docs must be visible
                    // to the pipeline's $match.
                    self.flush_ingest();
                    let t = Instant::now();
                    let r = self.handle_aggregate(pipeline);
                    self.metrics
                        .observe(names::ROUTER_AGG_NS, t.elapsed().as_nanos() as u64);
                    let _ = reply.send(r);
                }
                RouterRequest::Update { filter, set, reply } => {
                    // Read-your-writes for the filter: buffered inserts
                    // must be visible to the update's match.
                    self.flush_ingest();
                    let t = Instant::now();
                    let r = self.handle_update(filter, set);
                    self.metrics
                        .observe(names::ROUTER_UPDATE_NS, t.elapsed().as_nanos() as u64);
                    let _ = reply.send(r);
                }
                RouterRequest::Delete { filter, reply } => {
                    self.flush_ingest();
                    let t = Instant::now();
                    let r = self.handle_delete(filter);
                    self.metrics
                        .observe(names::ROUTER_DELETE_NS, t.elapsed().as_nanos() as u64);
                    let _ = reply.send(r);
                }
                RouterRequest::CreateIndex { spec, reply } => {
                    self.flush_ingest();
                    // Every member builds the index: secondaries serve
                    // reads from their own engines, so index state must
                    // exist cluster-wide, not just on primaries.
                    let mut result = Ok(());
                    for member in self.members.iter().flatten() {
                        match rpc(member, |reply| ShardRequest::CreateIndex {
                            spec: spec.clone(),
                            reply,
                        }) {
                            Ok(Ok(())) => {}
                            Ok(Err(e)) | Err(e) => result = Err(e),
                        }
                    }
                    let _ = reply.send(result);
                }
                RouterRequest::Stats { reply } => {
                    self.flush_ingest();
                    let _ = reply.send(RouterStatsReply {
                        inserts: self.inserts,
                        finds: self.finds,
                        map_version: self.map.version,
                        wire_bytes_out: self.wire_bytes_out,
                    });
                }
            }
        }
        // Drain on shutdown/disconnect so every contributor gets an ack.
        self.flush_ingest();
    }

    /// Flush the ingest buffer through the scatter path and ack every
    /// contributor of the flushed batch.
    fn flush_ingest(&mut self) {
        if self.ingest_buf.is_empty() {
            self.buffered_since = None;
            return;
        }
        let docs = std::mem::take(&mut self.ingest_buf);
        let acks = std::mem::take(&mut self.pending_acks);
        self.buffered_since = None;
        let t = Instant::now();
        let flushed = docs.len();
        let result = self.handle_insert_many(docs);
        self.metrics.observe(names::ROUTER_FLUSH_NS, t.elapsed().as_nanos() as u64);
        self.metrics.counter(names::ROUTER_INGEST_FLUSHES).inc();
        self.metrics.counter(names::ROUTER_INGEST_FLUSH_DOCS).add(flushed as u64);
        match result {
            Ok(rep) => {
                // Success covers the whole flush; each contributor is
                // acked with its own document count. The reroute total is
                // attributed to the first ack so aggregates stay exact.
                let mut rerouted = rep.rerouted;
                for (n, reply) in acks {
                    let _ = reply.send(Ok(InsertManyReply { inserted: n, rerouted }));
                    rerouted = 0;
                }
            }
            Err(e) => {
                for (_, reply) in acks {
                    let _ = reply.send(Err(e.clone()));
                }
            }
        }
    }

    fn refresh_map(&mut self) {
        if let Ok(map) = rpc(&self.config, |reply| ConfigRequest::GetMap { reply }) {
            self.metrics.counter(names::ROUTER_MAP_REFRESH).inc();
            self.map = map;
        }
    }

    fn num_shards(&self) -> usize {
        self.members.len()
    }

    /// Mailbox writes to `shard` target: the hinted primary member.
    fn write_tx(&self, shard: usize) -> &mpsc::Sender<ShardRequest> {
        &self.members[shard][self.primary_hint[shard]]
    }

    /// Rotate the primary hint for `shard` after a `NotPrimary` reject
    /// or a dead member: follow the reject's leader hint when it names
    /// a valid member, otherwise try the next member round-robin (an
    /// election in progress has no leader to name yet).
    fn update_primary_hint(&mut self, shard: usize, leader: Option<u32>) {
        let n = self.members[shard].len();
        self.primary_hint[shard] = match leader {
            Some(l) if (l as usize) < n => l as usize,
            _ => (self.primary_hint[shard] + 1) % n.max(1),
        };
    }

    /// Typed dead-shard error; counts the encounter. Returned when no
    /// member of `shard` can take a request, or when a member died
    /// after accepting a write (the ambiguous case the router must not
    /// blindly retry — see the module doc).
    fn shard_unavailable(&self, shard: usize) -> WireError {
        self.metrics.counter(names::ROUTER_SHARD_UNAVAILABLE).inc();
        WireError::ShardUnavailable { shard: shard as u32 }
    }

    /// Member index reads on `shard` prefer under the read preference.
    fn read_member(&self, shard: usize) -> usize {
        let n = self.members[shard].len();
        match self.read_pref {
            ReadPreference::Primary => self.primary_hint[shard],
            // Deterministic "any secondary": the member after the
            // hinted primary. Secondary reads serve from that member's
            // own MVCC snapshots and may trail the primary by the
            // replication lag (ARCHITECTURE.md §10).
            ReadPreference::Secondary if n > 1 => (self.primary_hint[shard] + 1) % n,
            ReadPreference::Secondary => 0,
        }
    }

    /// Send a read-path request to `shard`: the read-preference member
    /// first, degrading to any member whose mailbox is still open (a
    /// read served by a stale member is still a valid snapshot read).
    /// Returns the member that accepted the send plus the reply
    /// channel; every member dead ⇒ typed `ShardUnavailable`, never a
    /// hang.
    fn send_read<R>(
        &self,
        shard: usize,
        mk: impl Fn(Reply<R>) -> ShardRequest,
    ) -> Result<(usize, mpsc::Receiver<R>), WireError> {
        let n = self.members[shard].len();
        let start = self.read_member(shard);
        for k in 0..n {
            let m = (start + k) % n;
            let (tx, rx) = mpsc::channel();
            if self.members[shard][m].send(mk(tx)).is_ok() {
                if k > 0 {
                    // Preferred member was dead; record the degrade.
                    self.metrics.counter(names::ROUTER_SHARD_UNAVAILABLE).inc();
                }
                return Ok((m, rx));
            }
        }
        Err(self.shard_unavailable(shard))
    }

    /// Partition `docs` by owning shard. Hashed keys go through the AOT
    /// route kernel; ranged keys use scalar positions.
    fn partition(&self, docs: Vec<Document>) -> Result<Vec<Vec<Document>>, WireError> {
        let num_shards = self.num_shards();
        let mut per_shard: Vec<Vec<Document>> = (0..num_shards).map(|_| Vec::new()).collect();
        match self.map.key.kind {
            ShardKeyKind::Hashed => {
                let node: Vec<u32> = docs
                    .iter()
                    .map(|d| d.get_i64("node_id").unwrap_or(0).max(0) as u32)
                    .collect();
                let ts: Vec<u32> = docs
                    .iter()
                    .map(|d| d.get_i64("ts").unwrap_or(0).max(0) as u32)
                    .collect();
                let (bounds, owners) = self.map.kernel_tables();
                let out = self
                    .kernels
                    .route(&node, &ts, &bounds, &owners, num_shards)
                    .map_err(|e| WireError::Server(e.to_string()))?;
                // Exact sub-batch allocation from the kernel histogram.
                for (s, v) in per_shard.iter_mut().enumerate() {
                    v.reserve(out.counts[s] as usize);
                }
                for (doc, &shard) in docs.into_iter().zip(&out.shard_of) {
                    per_shard[shard as usize].push(doc);
                }
            }
            ShardKeyKind::Ranged => {
                for doc in docs {
                    let node = doc.get_i64("node_id").unwrap_or(0).max(0) as u32;
                    let ts = doc.get_i64("ts").unwrap_or(0).max(0) as u32;
                    let pos = self.map.key.position(node, ts);
                    per_shard[self.map.owner_of(pos).index()].push(doc);
                }
            }
        }
        Ok(per_shard)
    }

    fn handle_insert_many(&mut self, docs: Vec<Document>) -> Result<InsertManyReply, WireError> {
        self.inserts += 1;
        let total = docs.len();
        let mut pending = docs;
        let mut inserted = 0usize;
        let mut rerouted = 0usize;
        // Unordered retry loop: a concurrent split/migration bounces a
        // sub-batch at most a few times before the map stabilizes, and
        // a failover bounces it with `NotPrimary` until the new leader
        // is found. Both rejects happen before any mutation, so the
        // resend cannot double-insert. The loop is bounded by the
        // write-retry deadline, with jittered backoff between passes.
        let deadline = Instant::now() + Duration::from_millis(self.write_retry_ms);
        let mut backoff = Backoff::new(BACKOFF_BASE_US, BACKOFF_CAP_US);
        let mut first_pass = true;
        while !pending.is_empty() {
            if !first_pass {
                if Instant::now() >= deadline {
                    break;
                }
                backoff.wait();
                self.refresh_map();
                rerouted += pending.len();
            }
            first_pass = false;
            let per_shard = self.partition(std::mem::take(&mut pending))?;
            // Dispatch all sub-batches, then collect replies (concurrent
            // across shards — the shards process in parallel threads).
            let mut in_flight: Vec<(usize, Vec<Document>, mpsc::Receiver<_>)> = Vec::new();
            for (s, batch) in per_shard.into_iter().enumerate() {
                if batch.is_empty() {
                    continue;
                }
                self.wire_bytes_out += batch_wire_bytes(&batch);
                let (tx, rx) = mpsc::channel();
                match self.write_tx(s).send(ShardRequest::InsertBatch {
                    version: self.map.version,
                    docs: batch.clone(),
                    wc: self.wc,
                    reply: tx,
                }) {
                    Ok(()) => in_flight.push((s, batch, rx)),
                    Err(_) if self.members[s].len() > 1 => {
                        // The hinted member's mailbox is closed and the
                        // batch never reached it — safe to re-aim at
                        // another member next pass.
                        self.metrics.counter(names::ROUTER_SHARD_UNAVAILABLE).inc();
                        self.update_primary_hint(s, None);
                        pending.extend(batch);
                    }
                    Err(_) => return Err(self.shard_unavailable(s)),
                }
            }
            for (s, batch, rx) in in_flight {
                // The send was accepted; a dropped reply means the
                // member died mid-request and the batch's fate is
                // unknown — surface the typed error, never resend.
                let r = rx.recv().map_err(|_| self.shard_unavailable(s))?;
                match r {
                    Ok(rep) => {
                        inserted += rep.inserted;
                        for i in rep.wrong_owner {
                            pending.push(batch[i].clone());
                        }
                    }
                    Err(WireError::StaleVersion { .. }) => {
                        self.metrics.counter(names::ROUTER_STALE_RETRIES).inc();
                        pending.extend(batch);
                    }
                    Err(WireError::NotPrimary { leader, .. }) => {
                        self.metrics.counter(names::ROUTER_NOT_PRIMARY_RETRIES).inc();
                        self.update_primary_hint(s, leader);
                        pending.extend(batch);
                    }
                    Err(e) => return Err(e),
                }
            }
        }
        if !pending.is_empty() {
            return Err(WireError::Server(format!(
                "{} of {total} docs unroutable after retries",
                pending.len()
            )));
        }
        Ok(InsertManyReply { inserted, rerouted })
    }

    fn handle_find(
        &mut self,
        filter: Filter,
        opts: FindOptions,
    ) -> Result<FindReply, WireError> {
        self.finds += 1;
        self.wire_bytes_out += find_wire_bytes(&filter) * self.num_shards() as u64;
        let batch = opts.batch_size.unwrap_or(self.default_batch);
        // Scatter to the read-preference member of every shard.
        let mut rxs = Vec::with_capacity(self.num_shards());
        for s in 0..self.num_shards() {
            let (m, rx) = self.send_read(s, |reply| ShardRequest::Find {
                filter: filter.clone(),
                opts: opts.clone(),
                reply,
            })?;
            rxs.push((s, m, rx));
        }
        // Gather one stream per shard; sorted queries are k-way merged
        // across them in serve_router_batch.
        let mut cur = RouterCursor {
            streams: Vec::new(),
            sort: opts.sort.clone(),
            remaining: opts.limit,
            batch,
        };
        for (s, m, rx) in rxs {
            let rep = rx.recv().map_err(|_| self.shard_unavailable(s))??;
            // Donor of a published handoff: its leftover copies of the
            // range are orphans. The shard's own read fence drops them
            // once its SetMap lands; this router-side fence covers the
            // gap where the router already knows and the donor does not.
            let orphan_fence = match self.map.handoff {
                Some(h) if h.published && h.from.index() == s => Some((self.map.key, h.range)),
                _ => None,
            };
            let mut docs = rep.docs;
            if let Some((key, range)) = orphan_fence {
                drop_orphans(&mut docs, key, range, &self.metrics);
            }
            if !docs.is_empty() || rep.cursor.is_some() {
                cur.streams.push(ShardStream {
                    shard: s,
                    member: m,
                    cursor: rep.cursor,
                    buf: docs.into(),
                    orphan_fence,
                });
            }
        }
        let first = self.serve_router_batch(&mut cur)?;
        if first.cursor.is_some() {
            let id = self.next_cursor;
            self.next_cursor += 1;
            self.cursors.insert(id, cur);
            Ok(FindReply { docs: first.docs, cursor: Some(id) })
        } else {
            Ok(first)
        }
    }

    /// Cluster-wide count with a **version-uniform scatter**. Every
    /// shard's reply carries the chunk-map version it served under;
    /// per-shard counts only compose exactly when those versions agree
    /// (under one map, the donor-side fence and the destination's
    /// publish mask partition a migrating range between exactly the
    /// shards that map says hold it — see ARCHITECTURE.md §6.3). On
    /// disagreement — a SetMap push caught mid-broadcast — the scatter
    /// is simply retried; the skew window is one mailbox drain long.
    fn handle_count(&mut self, filter: Filter) -> Result<u64, WireError> {
        self.finds += 1;
        let deadline = Instant::now() + Duration::from_millis(self.write_retry_ms);
        let mut backoff = Backoff::new(BACKOFF_BASE_US, BACKOFF_CAP_US);
        let mut first_pass = true;
        loop {
            if !first_pass {
                self.metrics.counter(names::ROUTER_COUNT_RETRIES).inc();
                backoff.wait();
                self.refresh_map();
            }
            first_pass = false;
            self.wire_bytes_out += find_wire_bytes(&filter) * self.num_shards() as u64;
            let mut rxs = Vec::with_capacity(self.num_shards());
            for s in 0..self.num_shards() {
                let (_, rx) = self
                    .send_read(s, |reply| ShardRequest::Count { filter: filter.clone(), reply })?;
                rxs.push((s, rx));
            }
            let mut total = 0u64;
            let mut versions = Vec::with_capacity(self.num_shards());
            for (s, rx) in rxs {
                let rep = rx.recv().map_err(|_| self.shard_unavailable(s))??;
                total += rep.n;
                versions.push(rep.version);
            }
            if versions.windows(2).all(|w| w[0] == w[1]) {
                return Ok(total);
            }
            if Instant::now() >= deadline {
                return Err(WireError::Server(
                    "count: shards would not converge on one chunk-map version".into(),
                ));
            }
        }
    }

    /// Cluster-wide aggregation under the same **version-uniform
    /// scatter** as [`Self::handle_count`]: per-shard partial
    /// accumulator tables only compose exactly when every shard served
    /// under one chunk-map version (the donor-side fence and the
    /// destination's publish mask then partition a migrating range
    /// between exactly the shards the map says hold it — no document is
    /// folded twice or zero times). On version skew the scatter
    /// retries; the window is one mailbox drain long.
    ///
    /// In push-down mode (`agg_partial`) each shard ships one
    /// accumulator row per group it saw and the router merges the
    /// partials — `avg` stays a (sum, count) pair until the terminal
    /// finalize here, which is what makes the distributed mean exact.
    /// In full-ship baseline mode the shards ship every matching
    /// document and the router folds them centrally through the same
    /// reference executor the differential tests compare against.
    fn handle_aggregate(&mut self, pipeline: AggPipeline) -> Result<Vec<Document>, WireError> {
        self.finds += 1;
        let deadline = Instant::now() + Duration::from_millis(self.write_retry_ms);
        let mut backoff = Backoff::new(BACKOFF_BASE_US, BACKOFF_CAP_US);
        let mut first_pass = true;
        loop {
            if !first_pass {
                self.metrics.counter(names::ROUTER_AGG_RETRIES).inc();
                backoff.wait();
                self.refresh_map();
            }
            first_pass = false;
            self.wire_bytes_out += agg_wire_bytes(&pipeline) * self.num_shards() as u64;
            let mut rxs = Vec::with_capacity(self.num_shards());
            for s in 0..self.num_shards() {
                let (_, rx) = self.send_read(s, |reply| ShardRequest::Aggregate {
                    pipeline: pipeline.clone(),
                    partial: self.agg_partial,
                    reply,
                })?;
                rxs.push((s, rx));
            }
            // Gather every reply before merging: the merge is only
            // valid once the versions are known to agree.
            let mut replies = Vec::with_capacity(self.num_shards());
            let mut versions = Vec::with_capacity(self.num_shards());
            for (s, rx) in rxs {
                let rep = rx.recv().map_err(|_| self.shard_unavailable(s))??;
                versions.push(rep.version);
                replies.push(rep);
            }
            if versions.windows(2).all(|w| w[0] == w[1]) {
                let mut table = PartialTable::new();
                let mut shipped_docs = Vec::new();
                for rep in replies {
                    self.metrics
                        .counter(names::ROUTER_AGG_REPLY_BYTES)
                        .add(agg_reply_wire_bytes(&rep));
                    self.metrics
                        .counter(names::ROUTER_AGG_PARTIAL_ROWS)
                        .add(rep.rows.len() as u64);
                    self.metrics
                        .counter(names::ROUTER_AGG_DOCS_SHIPPED)
                        .add(rep.docs.len() as u64);
                    table.merge_rows(&pipeline, rep.rows);
                    shipped_docs.extend(rep.docs);
                }
                return Ok(if self.agg_partial {
                    pipeline.finalize(table)
                } else {
                    pipeline.execute_docs(&shipped_docs)
                });
            }
            if Instant::now() >= deadline {
                return Err(WireError::Server(
                    "aggregate: shards would not converge on one chunk-map version".into(),
                ));
            }
        }
    }

    /// Shards a filter-driven write must visit: a superset of the
    /// shards holding matching documents under the router's map.
    /// Broadcast is always correct; the fast path prunes to the owner
    /// set when the filter pins the shard key. With a handoff in
    /// flight the answer is always broadcast — two shards hold copies
    /// of the range and the donor-side fence arbitrates.
    fn target_shards(&self, filter: &Filter) -> Vec<usize> {
        let all: Vec<usize> = (0..self.num_shards()).collect();
        if self.map.handoff.is_some() {
            return all;
        }
        let Some(nodes) = exact_node_pins(filter) else { return all };
        let mut hit = vec![false; self.num_shards()];
        match self.map.key.kind {
            ShardKeyKind::Hashed => {
                // Hashed positions scatter (node, ts) pairs across the
                // ring, so only a fully pinned key routes.
                let Some(ts) = exact_int(filter, "ts") else { return all };
                for node in nodes {
                    hit[self.map.owner_of(self.map.key.position(node, ts)).index()] = true;
                }
            }
            ShardKeyKind::Ranged => {
                // Ranged positions are (node << 32) | ts: each node's
                // ts window is one contiguous position interval. The
                // bounds are widened to inclusive (a $lt hi keeps hi) —
                // targeting only ever needs a superset.
                let (ts_lo, ts_hi) = ts_bounds(filter);
                for node in nodes {
                    let lo = self.map.chunk_of(self.map.key.position(node, ts_lo));
                    let hi = self.map.chunk_of(self.map.key.position(node, ts_hi));
                    for c in lo..=hi {
                        hit[self.map.owners[c].index()] = true;
                    }
                }
            }
        }
        let picked: Vec<usize> =
            (0..self.num_shards()).filter(|&s| hit[s]).collect();
        if picked.is_empty() { all } else { picked }
    }

    /// Scatter a filter-driven write to its target shards, retrying
    /// per-shard rejections until the map settles. Shards that already
    /// applied the write are not re-sent to (`done`) **while the map
    /// stays put**; when the chunk-map version moves mid-retry, every
    /// `done` flag resets and the write re-broadcasts. The reset is
    /// what makes the write complete across a concurrent migration: at
    /// the first pass the destination can apply (successfully, to what
    /// it owns) while the matching documents of the moving range sit
    /// invisibly in its *staging* collection — once the migration
    /// publishes them, a `done` destination would never be re-sent to
    /// and the write would silently skip the moved range even though
    /// the donor rejected it all along. Re-application is safe —
    /// `StaleVersion`/`MigrationInFlight` rejections happen *before*
    /// any mutation, and a repeated `$set`/delete is idempotent on
    /// document state — but the reply counters overlap across passes,
    /// so the caller gets every reply each shard produced (outer index
    /// = shard, in pass order) and folds them with that in mind.
    fn scatter_write<R, F>(
        &mut self,
        filter: &Filter,
        request: F,
    ) -> Result<Vec<Vec<R>>, WireError>
    where
        F: Fn(u64, Reply<Result<R, WireError>>) -> ShardRequest,
        R: Send + 'static,
    {
        let mut replies: Vec<Vec<R>> =
            (0..self.num_shards()).map(|_| Vec::new()).collect();
        let mut done = vec![false; self.num_shards()];
        let deadline = Instant::now() + Duration::from_millis(self.write_retry_ms);
        let mut backoff = Backoff::new(BACKOFF_BASE_US, BACKOFF_CAP_US);
        loop {
            // Recompute targets each pass: a migration finishing
            // between passes can move matching documents to a shard
            // the previous owner set did not include.
            let targets: Vec<usize> = self
                .target_shards(filter)
                .into_iter()
                .filter(|&s| !done[s])
                .collect();
            if targets.is_empty() {
                return Ok(replies);
            }
            let mut rxs = Vec::with_capacity(targets.len());
            let mut pending = false;
            for &s in &targets {
                self.wire_bytes_out += find_wire_bytes(filter);
                let (tx, rx) = mpsc::channel();
                match self.write_tx(s).send(request(self.map.version, tx)) {
                    Ok(()) => rxs.push((s, rx)),
                    Err(_) if self.members[s].len() > 1 => {
                        // Never delivered — safe to re-aim at another
                        // member on the next pass.
                        self.metrics.counter(names::ROUTER_SHARD_UNAVAILABLE).inc();
                        self.update_primary_hint(s, None);
                        pending = true;
                    }
                    Err(_) => return Err(self.shard_unavailable(s)),
                }
            }
            let mut blocked = false;
            for (s, rx) in rxs {
                // Delivered but the member died before replying: the
                // leg's fate is unknown and `$set`/delete counters
                // would skew on a blind resend — surface the typed
                // error instead (see the module doc).
                let r = rx.recv().map_err(|_| self.shard_unavailable(s))?;
                match r {
                    Ok(rep) => {
                        done[s] = true;
                        replies[s].push(rep);
                    }
                    Err(WireError::StaleVersion { .. }) => {
                        self.metrics.counter(names::ROUTER_STALE_RETRIES).inc();
                        pending = true;
                    }
                    Err(WireError::MigrationInFlight { .. }) => {
                        self.metrics.counter(names::ROUTER_WRITE_BLOCKED_RETRIES).inc();
                        blocked = true;
                        pending = true;
                    }
                    Err(WireError::NotPrimary { leader, .. }) => {
                        self.metrics.counter(names::ROUTER_NOT_PRIMARY_RETRIES).inc();
                        self.update_primary_hint(s, leader);
                        pending = true;
                    }
                    Err(e) => return Err(e),
                }
            }
            if !pending {
                // Everything sent this pass landed; loop once more to
                // see whether the (unchanged) owner set is now covered.
                continue;
            }
            if Instant::now() >= deadline {
                return Err(WireError::Server(
                    "write: shards still rejecting after retries (migration stuck?)".into(),
                ));
            }
            if blocked {
                // The blocking migration needs its coordinator to make
                // progress; yield rather than hammer the donor.
                std::thread::sleep(Duration::from_millis(1));
            } else {
                // Stale map, mid-election, or dead hinted member:
                // decorrelated exponential backoff before the re-aim.
                backoff.wait();
            }
            let seen = self.map.version;
            self.refresh_map();
            if self.map.version != seen {
                // Chunks moved while shards were rejecting: documents
                // the write must reach may now be live on a shard that
                // already replied (published out of its staging, or
                // rebalanced onto it). Re-send everywhere; shards with
                // nothing new to apply answer idempotently.
                self.metrics.counter(names::ROUTER_WRITE_RESCATTERS).inc();
                done.iter_mut().for_each(|d| *d = false);
            }
        }
    }

    fn handle_update(&mut self, filter: Filter, set: Document) -> Result<UpdateReply, WireError> {
        let wc = self.wc;
        let replies = self.scatter_write(&filter, |version, reply| ShardRequest::Update {
            version,
            filter: filter.clone(),
            set: set.clone(),
            wc,
            reply,
        })?;
        // Fold per-shard reply histories. A shard re-sent after a map
        // change reports overlapping `matched` counts across its passes
        // (the same document can match twice), so `matched` takes each
        // shard's *latest* reply — the freshest view of what it owns
        // under the settled map. `modified` sums exactly: a `$set`
        // cannot re-modify a document it already changed.
        let mut out = UpdateReply::default();
        for shard_replies in &replies {
            if let Some(last) = shard_replies.last() {
                out.matched += last.matched;
            }
            out.modified += shard_replies.iter().map(|r| r.modified).sum::<u64>();
        }
        // A `$set` that un-matches its own documents can make a later
        // pass's `matched` view miss documents an earlier pass already
        // modified; never report fewer matched than modified.
        out.matched = out.matched.max(out.modified);
        Ok(out)
    }

    fn handle_delete(&mut self, filter: Filter) -> Result<DeleteReply, WireError> {
        let wc = self.wc;
        let replies = self.scatter_write(&filter, |version, reply| ShardRequest::Delete {
            version,
            filter: filter.clone(),
            wc,
            reply,
        })?;
        // Deleted counts sum exactly across passes and shards: a
        // document deletes at most once cluster-wide (in-range copies
        // are rejected on both migration ends until the handoff clears,
        // so a donor orphan and its published twin can never both be
        // deleted).
        Ok(DeleteReply {
            deleted: replies.iter().flatten().map(|r| r.deleted).sum(),
        })
    }

    /// Refill `stream` from its shard until it has a buffered head or
    /// its shard-side cursor is exhausted. The GetMore goes back to the
    /// member the cursor was opened on (cursor state is member-local);
    /// if that member has died, the typed `ShardUnavailable` tells the
    /// client this cursor is gone for a *retryable* reason — re-issue
    /// the find — rather than reading as quiet exhaustion.
    fn refill(&self, stream: &mut ShardStream) -> Result<(), WireError> {
        while stream.buf.is_empty() {
            let Some(c) = stream.cursor else { return Ok(()) };
            let member = &self.members[stream.shard][stream.member];
            let rep = rpc(member, |reply| ShardRequest::GetMore { cursor: c, reply })
                .map_err(|_| self.shard_unavailable(stream.shard))??;
            let mut docs = rep.docs;
            if let Some((key, range)) = stream.orphan_fence {
                drop_orphans(&mut docs, key, range, &self.metrics);
            }
            stream.buf.extend(docs);
            stream.cursor = rep.cursor;
        }
        Ok(())
    }

    /// Fill one client batch from the per-shard streams, pulling shard
    /// GetMores as needed. Unsorted finds drain the streams in shard
    /// order; sorted finds take the best head across streams each step
    /// (k-way merge) — each shard stream is itself fully sorted, so the
    /// merged output is globally ordered.
    fn serve_router_batch(&mut self, cur: &mut RouterCursor) -> Result<FindReply, WireError> {
        let want = match cur.remaining {
            Some(r) => cur.batch.min(r),
            None => cur.batch,
        };
        let mut docs = Vec::with_capacity(want);
        while docs.len() < want {
            let next = match &cur.sort {
                // Unsorted: drain one stream at a time in shard order —
                // only the head stream is ever refilled, so shards whose
                // results the limit never reaches get no GetMore.
                None => loop {
                    let Some(s) = cur.streams.first_mut() else { break None };
                    self.refill(s)?;
                    if s.buf.is_empty() {
                        cur.streams.remove(0); // cursor exhausted and dry
                        continue;
                    }
                    break Some(0);
                },
                // Sorted: every live stream needs a buffered head before
                // the heads can be compared; dry streams drop out.
                Some((field, dir)) => {
                    for s in cur.streams.iter_mut() {
                        self.refill(s)?;
                    }
                    cur.streams.retain(|s| !s.buf.is_empty() || s.cursor.is_some());
                    best_head(&cur.streams, field, *dir)
                }
            };
            let Some(i) = next else { break };
            // lint: allow(panic, both arms above only yield a stream index
            // after refill() gave it a buffered head)
            docs.push(cur.streams[i].buf.pop_front().expect("head refilled above"));
        }
        if let Some(r) = cur.remaining.as_mut() {
            *r -= docs.len();
        }
        let exhausted = cur
            .streams
            .iter()
            .all(|s| s.buf.is_empty() && s.cursor.is_none());
        let limit_hit = cur.remaining == Some(0);
        Ok(FindReply { docs, cursor: (!exhausted && !limit_hit).then_some(0) })
    }

    fn handle_get_more(&mut self, cursor: u64) -> Result<FindReply, WireError> {
        let mut cur = self
            .cursors
            .remove(&cursor)
            .ok_or(WireError::UnknownCursor(cursor))?;
        let mut rep = self.serve_router_batch(&mut cur)?;
        if rep.cursor.is_some() {
            self.cursors.insert(cursor, cur);
            rep.cursor = Some(cursor);
        }
        Ok(rep)
    }
}

/// Drop documents whose shard-key position falls in a published
/// handoff's range — leftover donor copies the destination already
/// serves. Documents missing a key field (a projection stripped it)
/// are kept: the fence must never lose a legitimate document, and the
/// donor's own shard-side fence still covers them one SetMap later.
fn drop_orphans(docs: &mut Vec<Document>, key: ShardKey, range: (u64, u64), metrics: &Registry) {
    let before = docs.len();
    docs.retain(|d| {
        let (Some(node), Some(ts)) = (d.get_i64("node_id"), d.get_i64("ts")) else {
            return true;
        };
        let pos = key.position_i64(node, ts);
        !(range.0 <= pos && pos <= range.1)
    });
    if docs.len() < before {
        metrics
            .counter(names::ROUTER_ORPHANS_FILTERED)
            .add((before - docs.len()) as u64);
    }
}

/// Exact `node_id` pins from a filter's top-level conjuncts (`$in`
/// list or equality), if every pinned value is a representable u32.
/// `None` means the filter does not pin the node — broadcast.
fn exact_node_pins(filter: &Filter) -> Option<Vec<u32>> {
    if let Some(values) = filter.in_values("node_id") {
        let mut nodes = Vec::with_capacity(values.len());
        for v in values {
            match v {
                Value::Int(n) if (0..=u32::MAX as i64).contains(n) => nodes.push(*n as u32),
                _ => return None,
            }
        }
        return (!nodes.is_empty()).then_some(nodes);
    }
    exact_int(filter, "node_id").map(|n| vec![n])
}

/// The single value `field` is pinned to, when the filter's range
/// bounds collapse to one representable u32.
fn exact_int(filter: &Filter, field: &str) -> Option<u32> {
    match filter.index_range(field) {
        Some((Some(Value::Int(lo)), Some(Value::Int(hi))))
            if lo == hi && (0..=u32::MAX as i64).contains(&lo) =>
        {
            Some(lo as u32)
        }
        _ => None,
    }
}

/// `ts` bounds for ranged-key targeting, widened to an inclusive u32
/// window (missing bounds span the whole axis).
fn ts_bounds(filter: &Filter) -> (u32, u32) {
    let mut lo = 0u32;
    let mut hi = u32::MAX;
    if let Some((l, h)) = filter.index_range("ts") {
        if let Some(Value::Int(v)) = l {
            lo = v.clamp(0, u32::MAX as i64) as u32;
        }
        if let Some(Value::Int(v)) = h {
            hi = v.clamp(0, u32::MAX as i64) as u32;
        }
    }
    (lo, hi)
}

/// Index of the stream whose head document comes next in the merged
/// order: minimum sort key for ascending, maximum for descending, over
/// [`Value::cmp_total`] with missing fields sorting as `Null` (the same
/// rule each shard sorts by). Ties keep the lowest shard index, so the
/// merge is deterministic. `None` when every stream is dry.
fn best_head(streams: &[ShardStream], field: &str, dir: SortDir) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, s) in streams.iter().enumerate() {
        let Some(head) = s.buf.front() else { continue };
        let better = match best {
            None => true,
            Some(b) => {
                // lint: allow(panic, best is only ever set to a stream
                // whose head was just observed)
                let incumbent = streams[b].buf.front().expect("best stream has a head");
                let ord = head
                    .get(field)
                    .unwrap_or(&Value::Null)
                    .cmp_total(incumbent.get(field).unwrap_or(&Value::Null));
                match dir {
                    SortDir::Asc => ord == std::cmp::Ordering::Less,
                    SortDir::Desc => ord == std::cmp::Ordering::Greater,
                }
            }
        };
        if better {
            best = Some(i);
        }
    }
    best
}

// Broader coverage for the router lives in cluster-level integration
// tests (`rust/tests/cluster_live.rs`) since a router is meaningless
// without shards; `partition` is additionally covered against the
// fallback in the runtime roundtrip suite.

/// Helper used by ablation benches: route a batch scalar-only (bypassing
/// the kernel service) for A1 comparisons.
pub fn partition_scalar(
    map: &ChunkMap,
    docs: &[Document],
    num_shards: usize,
) -> Vec<Vec<usize>> {
    let mut per_shard: Vec<Vec<usize>> = (0..num_shards).map(|_| Vec::new()).collect();
    for (i, doc) in docs.iter().enumerate() {
        let node = doc.get_i64("node_id").unwrap_or(0).max(0) as u32;
        let ts = doc.get_i64("ts").unwrap_or(0).max(0) as u32;
        let pos = map.key.position(node, ts);
        per_shard[map.owner_of(pos).index()].push(i);
    }
    per_shard
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mongo::sharding::chunk::ShardKey;

    #[test]
    fn scalar_partition_agrees_with_map_owner() {
        let map = ChunkMap::pre_split(ShardKey::hashed(), 4, 2);
        let docs: Vec<Document> = (0..100)
            .map(|i| Document::new().set("ts", i as i64).set("node_id", (i * 7) as i64))
            .collect();
        let parts = partition_scalar(&map, &docs, 4);
        assert_eq!(parts.iter().map(Vec::len).sum::<usize>(), 100);
        for (s, idxs) in parts.iter().enumerate() {
            for &i in idxs {
                let node = docs[i].get_i64("node_id").unwrap() as u32;
                let ts = docs[i].get_i64("ts").unwrap() as u32;
                assert_eq!(map.owner_of(map.key.position(node, ts)).index(), s);
            }
        }
    }

    #[test]
    fn best_head_picks_min_asc_max_desc_and_skips_dry_streams() {
        let stream = |shard: usize, ts: &[i64]| ShardStream {
            shard,
            member: 0,
            cursor: None,
            buf: ts.iter().map(|&t| Document::new().set("ts", t)).collect(),
            orphan_fence: None,
        };
        let streams = vec![stream(0, &[5, 9]), stream(1, &[]), stream(2, &[3, 4])];
        assert_eq!(best_head(&streams, "ts", SortDir::Asc), Some(2));
        assert_eq!(best_head(&streams, "ts", SortDir::Desc), Some(0));
        assert_eq!(best_head(&streams[1..2], "ts", SortDir::Asc), None);
        // Ties resolve to the lowest shard index (deterministic merge).
        let tied = vec![stream(0, &[7]), stream(1, &[7])];
        assert_eq!(best_head(&tied, "ts", SortDir::Asc), Some(0));
        assert_eq!(best_head(&tied, "ts", SortDir::Desc), Some(0));
    }

    #[test]
    fn write_targeting_extracts_key_pins() {
        use crate::mongo::query::CmpOp;

        // $in pins a node list.
        let f = Filter::and(vec![
            Filter::is_in("node_id", vec![Value::Int(3), Value::Int(9)]),
            Filter::cmp("ts", CmpOp::Gte, 100i64),
            Filter::cmp("ts", CmpOp::Lt, 200i64),
        ]);
        assert_eq!(exact_node_pins(&f), Some(vec![3, 9]));
        // ts bounds widen $lt to inclusive (a superset is fine).
        assert_eq!(ts_bounds(&f), (100, 200));
        assert_eq!(exact_int(&f, "ts"), None);

        // Equality pins a single node; an exact ts pins fully.
        let f = Filter::and(vec![Filter::eq("node_id", 7i64), Filter::eq("ts", 42i64)]);
        assert_eq!(exact_node_pins(&f), Some(vec![7]));
        assert_eq!(exact_int(&f, "ts"), Some(42));

        // No pin, negative pin, or non-int pin → broadcast.
        assert_eq!(exact_node_pins(&Filter::True), None);
        assert_eq!(exact_node_pins(&Filter::eq("node_id", -1i64)), None);
        assert_eq!(exact_node_pins(&Filter::eq("node_id", "x")), None);
        assert_eq!(ts_bounds(&Filter::True), (0, u32::MAX));
    }

    #[test]
    fn drop_orphans_filters_by_position_and_keeps_unkeyed_docs() {
        let key = ShardKey::ranged();
        let metrics = Registry::new();
        let doc = |node: i64, ts: i64| Document::new().set("node_id", node).set("ts", ts);
        let range = (key.position(5, 0), key.position(5, u32::MAX));
        let mut docs = vec![
            doc(4, 10),                         // outside the range: kept
            doc(5, 10),                         // inside: dropped
            Document::new().set("load", 1.5),   // no key fields: kept
            doc(5, 999),                        // inside: dropped
            doc(6, 0),                          // outside: kept
            doc(-2, 10),                        // clamps to node 0: kept
        ];
        drop_orphans(&mut docs, key, range, &metrics);
        assert_eq!(docs.len(), 4);
        assert!(docs.iter().all(|d| d.get_i64("node_id") != Some(5)));
        assert_eq!(metrics.counter(names::ROUTER_ORPHANS_FILTERED).get(), 2);

        // Negative keys clamp (never wrap): a node-0 fence catches
        // them, exactly like the shard-side `ReadFence::excludes`.
        let zero_range = (key.position(0, 0), key.position(0, u32::MAX));
        let mut docs = vec![doc(-2, 10), doc(0, -7), doc(1, 10)];
        drop_orphans(&mut docs, key, zero_range, &metrics);
        assert_eq!(docs.len(), 1);
        assert_eq!(docs[0].get_i64("node_id"), Some(1));
    }
}
