//! Live cluster assembly: wires config server, shard servers, and
//! routers into a running cluster of threads, and drives the balancer.
//!
//! This is the in-process analogue of the paper's run-script bring-up:
//! role assignment happens in `hpc::runscript`, which calls
//! [`Cluster::start`] with the storage directories the Lustre layer
//! assigned to each shard.

use std::sync::mpsc;

use anyhow::{Context, Result};

use crate::config::StoreConfig;
use crate::metrics::{names, Registry};
use crate::mongo::client::MongoClient;
use crate::mongo::server::config::ConfigServer;
use crate::mongo::server::replica::ReplicaConfig;
use crate::mongo::server::router::{Router, RouterMailbox, RouterRequest};
use crate::mongo::server::shard::ShardServer;
use crate::mongo::sharding::balancer::{plan_moves_with_loads, BalancerPolicy, ShardLoad};
use crate::mongo::sharding::chunk::ShardKey;
use crate::mongo::sharding::migration;
use crate::mongo::storage::{CheckpointStats, EngineOptions, LocalDir, StorageDir};
use crate::mongo::wire::{rpc, ConfigRequest, ConfigStatsReply, ShardRequest, ShardStatsReply};
use crate::runtime::Kernels;
use crate::util::ids::{RouterId, ShardId};

/// Cluster shape + store knobs.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    pub shards: u32,
    pub routers: u32,
    pub config_replicas: u32,
    /// Initial chunks per shard (hashed pre-split).
    pub chunks_per_shard: u32,
    pub store: StoreConfig,
}

impl ClusterSpec {
    pub fn small(shards: u32, routers: u32) -> Self {
        Self {
            shards,
            routers,
            config_replicas: 3,
            chunks_per_shard: 2,
            store: StoreConfig::default(),
        }
    }

    pub fn key(&self) -> ShardKey {
        ShardKey { kind: self.store.shard_key }
    }
}

/// Aggregated cluster statistics.
#[derive(Clone, Debug, Default)]
pub struct ClusterStats {
    pub docs: u64,
    pub bytes: u64,
    pub index_entries: u64,
    pub chunks: usize,
    pub map_version: u64,
    pub migrations: u64,
    /// Migrations the coordinator aborted and cleaned up (awaited
    /// destination rollback — nothing orphaned).
    pub migrations_failed: u64,
    pub per_shard_docs: Vec<u64>,
    /// Per-shard byte footprint the byte-aware balancer planned with.
    pub per_shard_bytes: Vec<u64>,
}

/// A running live cluster.
pub struct Cluster {
    spec: ClusterSpec,
    config: mpsc::Sender<ConfigRequest>,
    /// Member-0 mailbox per logical shard — the admin/balancer plane
    /// (stats, checkpoints, migrations) speaks to the bootstrap member.
    shards: Vec<mpsc::Sender<ShardRequest>>,
    /// All replica-set member mailboxes, `members[shard][member]`
    /// (a single column per shard when `--replicas 1`).
    members: Vec<Vec<mpsc::Sender<ShardRequest>>>,
    routers: Vec<RouterMailbox>,
    joins: Vec<std::thread::JoinHandle<()>>,
    metrics: Registry,
    policy: BalancerPolicy,
}

impl Cluster {
    /// Start all roles. `dir_for` supplies each shard's storage
    /// directory (Lustre-assigned in the full stack, temp dirs in
    /// tests); with `--replicas > 1` the extra members get scratch
    /// directories — tests that exercise member restart/rejoin use
    /// [`Cluster::start_with_members`] to place every member.
    pub fn start(
        spec: ClusterSpec,
        dir_for: impl Fn(ShardId) -> Result<Box<dyn StorageDir>>,
        kernels: Kernels,
        metrics: Registry,
    ) -> Result<Cluster> {
        Self::start_with_members(
            spec,
            |sid, member| {
                if member == 0 {
                    dir_for(sid)
                } else {
                    Ok(Box::new(LocalDir::temp(&format!("{sid}-m{member}"))?))
                }
            },
            kernels,
            metrics,
        )
    }

    /// Start all roles with per-member storage placement: each replica
    /// of each shard is a full [`ShardServer`] on its own directory
    /// (one mongod per directory, as in the paper's deployment).
    pub fn start_with_members(
        mut spec: ClusterSpec,
        dir_for: impl Fn(ShardId, u32) -> Result<Box<dyn StorageDir>>,
        kernels: Kernels,
        metrics: Registry,
    ) -> Result<Cluster> {
        anyhow::ensure!(spec.shards > 0 && spec.routers > 0, "degenerate topology");
        let replicas = spec.store.replicas.max(1);
        if replicas > 1 && spec.store.balancer {
            // Chunk migration streams records between shards outside
            // the oplog, so it cannot coexist with replication yet:
            // secondaries would never see migrated data. Replicated
            // clusters run with static chunk placement.
            eprintln!(
                "warn: balancer disabled: chunk migration bypasses the oplog (replicas > 1)"
            );
            spec.store.balancer = false;
        }

        // Pre-create every mailbox so roles can reference each other
        // before any thread runs.
        let (config_tx, config_rx) = mpsc::channel();
        let mut members: Vec<Vec<mpsc::Sender<ShardRequest>>> = Vec::new();
        let mut member_rxs: Vec<Vec<mpsc::Receiver<ShardRequest>>> = Vec::new();
        for _ in 0..spec.shards {
            let mut txs = Vec::new();
            let mut rxs = Vec::new();
            for _ in 0..replicas {
                let (tx, rx) = mpsc::channel();
                txs.push(tx);
                rxs.push(rx);
            }
            members.push(txs);
            member_rxs.push(rxs);
        }
        let shard_txs: Vec<mpsc::Sender<ShardRequest>> =
            members.iter().map(|m| m[0].clone()).collect();

        let mut config_server = ConfigServer::new(
            spec.key(),
            spec.shards,
            spec.chunks_per_shard,
            spec.config_replicas,
            metrics.clone(),
        );
        let initial_map = config_server.initial_map();
        // Every member of every set tracks the chunk map: SetMap is
        // broadcast to all of them, so a promoted secondary serves with
        // a current map, not a bootstrap-era one.
        config_server.set_shards(members.iter().flatten().cloned().collect());

        let mut joins = Vec::new();
        joins.push(config_server.spawn_with(config_rx));

        let engine_opts = EngineOptions {
            journal: spec.store.journal,
            compress_checkpoints: spec.store.compress_checkpoints,
            checkpoint_bytes: spec.store.checkpoint_bytes,
            journal_segments: spec.store.journal_segments,
            full_checkpoint_chain: spec.store.full_checkpoint_chain,
            snapshot_retention: spec.store.snapshot_retention,
        };
        for (s, rxs) in member_rxs.into_iter().enumerate() {
            let id = ShardId(s as u32);
            for (m, rx) in rxs.into_iter().enumerate() {
                let replica = (replicas > 1).then(|| ReplicaConfig {
                    member: m as u32,
                    peers: members[s].clone(),
                    election_timeout_ms: spec.store.election_timeout_ms,
                    heartbeat_ms: spec.store.heartbeat_ms,
                    bootstrap_primary: m == 0,
                });
                let server = ShardServer::new(
                    id,
                    dir_for(id, m as u32)
                        .with_context(|| format!("storage dir for {id} member {m}"))?,
                    initial_map.clone(),
                    config_tx.clone(),
                    kernels.clone(),
                    metrics.clone(),
                    engine_opts.clone(),
                    spec.store.max_chunk_docs,
                    spec.store.cursor_batch,
                    spec.store.reader_threads,
                    replica,
                )?;
                joins.push(server.spawn_with(rx));
            }
        }

        let mut routers = Vec::new();
        for i in 0..spec.routers {
            let router = Router::new(
                RouterId(i),
                initial_map.clone(),
                members.clone(),
                config_tx.clone(),
                kernels.clone(),
                metrics.clone(),
                spec.store.cursor_batch,
                spec.store.router_flush_docs,
                std::time::Duration::from_millis(spec.store.flush_interval_ms),
                spec.store.agg_partial,
                spec.store.write_concern,
                spec.store.read_preference,
                spec.store.write_retry_ms,
            );
            let (tx, join) = router.spawn();
            routers.push(tx);
            joins.push(join);
        }

        // Migration reconciliation: finish (forward) or drop (back)
        // whatever chunk migration a previous job's kill interrupted,
        // before any client traffic — see `sharding::migration::recover`.
        migration::recover(&shard_txs, &metrics)
            .context("migration reconciliation at startup")?;

        let policy = BalancerPolicy {
            byte_threshold: spec.store.balancer_bytes,
            ..Default::default()
        };
        Ok(Cluster {
            spec,
            config: config_tx,
            shards: shard_txs,
            members,
            routers,
            joins,
            metrics,
            policy,
        })
    }

    pub fn client(&self) -> MongoClient {
        MongoClient::new(self.routers.clone())
    }

    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn router_mailboxes(&self) -> &[RouterMailbox] {
        &self.routers
    }

    /// Shard mailboxes — the crash-matrix kill-window tests drive the
    /// migration wire protocol against them directly to freeze the
    /// cluster in precise mid-protocol states. With replicas these are
    /// the member-0 (bootstrap-primary) mailboxes.
    pub fn shard_mailboxes(&self) -> &[mpsc::Sender<ShardRequest>] {
        &self.shards
    }

    /// Mailboxes of one shard's replica-set members.
    pub fn member_mailboxes(&self, shard: usize) -> &[mpsc::Sender<ShardRequest>] {
        &self.members[shard]
    }

    /// Kill one replica-set member (failover drills): its event loop
    /// exits without checkpointing or handing anything off — peers and
    /// routers just see a dead mailbox, exactly like a crashed mongod.
    /// Durable state stays on its directory; member *restart* (rejoin
    /// with persisted term, catch-up by oplog tailing) is exercised at
    /// the `ShardServer` level by the crash harness, which controls the
    /// replacement mailbox wiring.
    pub fn kill_member(&self, shard: usize, member: usize) {
        let _ = self.members[shard][member].send(ShardRequest::Shutdown);
    }

    /// One balancer round: plan against the current chunk table *and*
    /// the per-shard byte loads, then execute the proposed migrations
    /// through the streaming crash-safe protocol
    /// (`sharding::migration::execute`) — chunk data really moves
    /// between shard engines, in bounded batches that interleave with
    /// served requests. Returns the number of chunks moved. Failures
    /// are awaited and cleaned up (the destination's partial copy is
    /// deleted, the config rolls back) and counted in the
    /// `cluster.migrations_failed` metric.
    pub fn run_balancer_round(&self) -> Result<usize> {
        if !self.spec.store.balancer {
            return Ok(0);
        }
        let map = rpc(&self.config, |reply| ConfigRequest::GetMap { reply })
            .map_err(|e| anyhow::anyhow!("config: {e}"))?;
        let loads = self.shard_loads()?;
        let moves = plan_moves_with_loads(&map.owners, &loads, self.policy);
        let mut moved = 0;
        for m in moves {
            // Re-read: chunk indices shift as splits/moves land.
            let map = rpc(&self.config, |reply| ConfigRequest::GetMap { reply })
                .map_err(|e| anyhow::anyhow!("config: {e}"))?;
            if m.chunk >= map.num_chunks() || map.owners[m.chunk] != m.from {
                continue; // plan went stale; next round will retry
            }
            match migration::execute(
                &self.config,
                &self.shards,
                m.chunk,
                m.to,
                self.spec.store.migration_batch_docs,
                &self.metrics,
            ) {
                Ok(_) => moved += 1,
                // The executor already rolled back (or forward) and
                // counted the failure; the next round replans against
                // fresh stats.
                Err(_) => {}
            }
        }
        Ok(moved)
    }

    /// Per-shard byte loads for the byte-aware balancer: live document
    /// bytes plus the storage lifecycle's on-disk journal and
    /// delta-chain bytes — the shard's real footprint on the shared
    /// filesystem. An unreachable shard fails the round: reporting it
    /// as zero-loaded would make the dead shard the byte-lightest and
    /// therefore the preferred (and doomed) migration receiver.
    fn shard_loads(&self) -> Result<Vec<ShardLoad>> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let st = rpc(s, |reply| ShardRequest::Stats { reply })
                    .map_err(|e| anyhow::anyhow!("shard {i} stats: {e}"))?;
                Ok(ShardLoad {
                    bytes: st.collection.bytes + st.journal_disk_bytes + st.delta_disk_bytes,
                })
            })
            .collect()
    }

    /// Admin command: checkpoint every shard engine now (end-of-job
    /// persistence barrier, or operator-forced compaction). Returns one
    /// [`CheckpointStats`] per shard, in shard order.
    pub fn checkpoint_all(&self) -> Result<Vec<CheckpointStats>> {
        let mut stats = Vec::with_capacity(self.shards.len());
        for (i, s) in self.shards.iter().enumerate() {
            let ck = rpc(s, |reply| ShardRequest::Checkpoint { reply })
                .map_err(|e| anyhow::anyhow!("shard {i}: {e}"))?
                .map_err(|e| anyhow::anyhow!("shard {i}: {e}"))?;
            stats.push(ck);
        }
        Ok(stats)
    }

    pub fn shard_stats(&self) -> Vec<ShardStatsReply> {
        self.shards
            .iter()
            .filter_map(|s| rpc(s, |reply| ShardRequest::Stats { reply }).ok())
            .collect()
    }

    pub fn config_stats(&self) -> Option<ConfigStatsReply> {
        rpc(&self.config, |reply| ConfigRequest::Stats { reply }).ok()
    }

    pub fn stats(&self) -> ClusterStats {
        let shard_stats = self.shard_stats();
        let config = self.config_stats().unwrap_or_default();
        ClusterStats {
            docs: shard_stats.iter().map(|s| s.collection.docs).sum(),
            bytes: shard_stats.iter().map(|s| s.collection.bytes).sum(),
            index_entries: shard_stats.iter().map(|s| s.collection.index_entries).sum(),
            chunks: config.chunks,
            map_version: config.version,
            migrations: config.migrations_done,
            migrations_failed: self.metrics.counter(names::CLUSTER_MIGRATIONS_FAILED).get(),
            per_shard_docs: shard_stats.iter().map(|s| s.collection.docs).collect(),
            per_shard_bytes: shard_stats
                .iter()
                .map(|s| s.collection.bytes + s.journal_disk_bytes + s.delta_disk_bytes)
                .collect(),
        }
    }

    /// Graceful shutdown: stop routers, then every shard member, then
    /// config.
    pub fn shutdown(mut self) {
        for r in &self.routers {
            let _ = r.send(RouterRequest::Shutdown);
        }
        for m in self.members.iter().flatten() {
            let _ = m.send(ShardRequest::Shutdown);
        }
        let _ = self.config.send(ConfigRequest::Shutdown);
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}
