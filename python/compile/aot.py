"""AOT lowering: JAX/Pallas model → HLO text artifacts for the Rust runtime.

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is HLO *text*, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Alongside each ``<name>.hlo.txt`` a ``manifest.json`` records the
input/output specs so the Rust loader can validate shapes at startup.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to HLO text via stablehlo → XlaComputation."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifact_defs():
    """name → (fn, [input ShapeDtypeStructs], [output names])."""
    u32, i32, f32 = jnp.uint32, jnp.int32, jnp.float32
    return {
        f"route_b{model.ROUTE_B}_c{model.ROUTE_C}_s{model.ROUTE_S}": (
            model.route_batch,
            [
                _spec((model.ROUTE_B,), u32),  # node_id
                _spec((model.ROUTE_B,), u32),  # ts_min
                _spec((model.ROUTE_C,), u32),  # boundaries
                _spec((model.ROUTE_C,), i32),  # chunk_to_shard
            ],
            ["shard_of", "counts", "hashes"],
        ),
        f"filter_b{model.FILTER_B}_w{model.FILTER_W}": (
            model.filter_batch,
            [
                _spec((model.FILTER_B,), u32),  # ts_min
                _spec((model.FILTER_B,), u32),  # node_id
                _spec((1,), u32),  # ts_lo
                _spec((1,), u32),  # ts_hi
                _spec((model.FILTER_W,), u32),  # node_bitmap
            ],
            ["mask", "count"],
        ),
        f"stats_b{model.STATS_B}_m{model.STATS_M}": (
            model.stats_batch,
            [_spec((model.STATS_B, model.STATS_M), f32)],  # metrics
            ["min", "max", "mean"],
        ),
    }


def lower_artifact(name, fn, in_specs):
    lowered = jax.jit(fn).lower(*in_specs)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "shapes": {
            "route_b": model.ROUTE_B,
            "route_c": model.ROUTE_C,
            "route_s": model.ROUTE_S,
            "filter_b": model.FILTER_B,
            "filter_w": model.FILTER_W,
            "stats_b": model.STATS_B,
            "stats_m": model.STATS_M,
        },
        "artifacts": {},
    }
    for name, (fn, in_specs, out_names) in artifact_defs().items():
        text = lower_artifact(name, fn, in_specs)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in in_specs
            ],
            "outputs": out_names,
        }
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
