//! `hpcstore` — a sharded document store deployed as a *queued job* on a
//! shared HPC architecture.
//!
//! This crate reproduces, as a complete system, the paper
//! *"Deploying a sharded MongoDB cluster as a queued job on a shared HPC
//! architecture"* (Saxton & Squaire, CS.DC 2022). It implements every
//! substrate the paper depends on:
//!
//! * [`mongo`] — a MongoDB-like sharded document store (config servers,
//!   shard servers running a WiredTiger-like storage engine, and `mongos`
//!   routers) built from scratch.
//! * [`hpc`] — the shared-HPC substrate: a Torque/Moab-like batch
//!   scheduler, a Lustre-like striped parallel filesystem, a Gemini-like
//!   interconnect cost model, and the paper's run-script deployment
//!   orchestration.
//! * [`runtime`] — the PJRT execution engine that loads AOT-compiled
//!   JAX/Pallas artifacts (shard-key routing and predicate-filter kernels)
//!   and runs them on the router/shard hot paths.
//! * [`workload`] — the OVIS-style node-metric corpus generator, CSV
//!   corpus store, and the paper's ingest (`insertMany`) and conditional
//!   `find` drivers.
//! * [`sim`] — a discrete-event simulator calibrated from live
//!   microbenchmarks, used to regenerate the paper's cluster-scale
//!   figures (32–256 nodes) on a single machine.
//!
//! Python/JAX runs only at build time (`make artifacts`); the request path
//! is pure Rust + PJRT.

// The whole tree is safe Rust today (the byte-level raw matching in
// `bson.rs` is all bounds-checked slices); any future `unsafe` must
// carry a scoped `#[allow(unsafe_code)]` and survive the Miri CI job.
#![deny(unsafe_code)]

pub mod analysis;
pub mod benchkit;
pub mod cli;
pub mod config;
pub mod json;
pub mod hpc;
pub mod metrics;
pub mod mongo;
pub mod runtime;
pub mod sim;
pub mod testing;
pub mod util;
pub mod workload;

#[cfg(feature = "pjrt")]
pub use runtime::engine::Engine;
