//! Aggregation pipeline: `$match / $project / $group / $sort / $limit`
//! with shard-side partial accumulators.
//!
//! The pipeline executes in two phases (ARCHITECTURE.md §7.4):
//!
//! * **Shard fold** — each shard evaluates `$match` with the planner +
//!   zero-copy raw matcher over a pinned MVCC snapshot and folds every
//!   matching record into a per-group [`AccState`] table using
//!   [`RawDoc`] field probes (no full decode on the accumulate path).
//!   The reply is one [`AggRow`] table: O(groups), not O(matched docs).
//! * **Router merge** — partial states merge with a closed algebra
//!   (count/sum add, min/max fold under [`Value::cmp_total`]); `avg`
//!   travels as a (sum, count) pair and divides only at finalize, since
//!   a mean of per-shard means would weight shards, not documents.
//!   Final `$sort`/`$limit` run over the merged, finalized rows.
//!
//! [`AggPipeline::execute_docs`] is the naive decode-everything
//! reference executor: it folds decoded [`Document`]s through the same
//! finalize step and doubles as the router's central fold for the
//! full-ship baseline (`--agg-partial 0`). The distributed raw-probe
//! fold + merge must agree with it bit-for-bit — sealed by the
//! differential property test `sharded_fold_agrees_with_reference`.
//!
//! Semantics (the subset the paper's rollups need, kept deterministic):
//! * Group keys are scalars; a missing `$group` field — or a
//!   container-valued one — groups under null. `Int(2)` and `F64(2.0)`
//!   are distinct keys (grouping is by value identity, not numeric
//!   coercion); merged rows order by [`GroupKey`]'s total order.
//! * `count` counts documents; `sum`/`avg` accumulate numeric values in
//!   f64 and ignore non-numeric or missing fields (`sum` of none is
//!   `0.0`, `avg` of none is null); `min`/`max` fold any present value
//!   under the total order and are null over an empty set.
//! * `$project` restricts which fields the group/accumulate stages can
//!   see; `$sort` orders finalized rows by an output field (missing →
//!   null, ties keep the group-key order — the same missing/tie posture
//!   as the router's k-way document merge).

use std::cmp::Ordering;
use std::collections::HashMap;

use super::bson::{Document, RawDoc, RawValue, Value};
use super::query::{Filter, SortDir};

/// Accumulator operator inside `$group`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccOp {
    /// Documents in the group (the accumulated field is ignored).
    Count,
    /// f64 sum of numeric values (0.0 over the empty set).
    Sum,
    /// Minimum under the total value order (null over the empty set).
    Min,
    /// Maximum under the total value order (null over the empty set).
    Max,
    /// Mean of numeric values — carried as a (sum, count) pair and
    /// divided only at finalize (null over the empty set).
    Avg,
}

/// One named accumulator: `name: {$op: "$field"}`.
#[derive(Clone, Debug, PartialEq)]
pub struct AccSpec {
    pub name: String,
    pub op: AccOp,
    pub field: String,
}

/// The pipeline. Stages are fixed-order (match → project → group →
/// sort → limit), which is the shape every shard can push down whole.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct AggPipeline {
    /// `$match` ({} when absent).
    pub filter: Filter,
    /// `$project`: the fields later stages may see (None = all).
    pub project: Option<Vec<String>>,
    /// `$group` key field (None = one global group).
    pub group_by: Option<String>,
    /// The `$group` accumulators, in output order.
    pub accs: Vec<AccSpec>,
    /// Final `$sort` on an output field (`_id` or an accumulator name).
    pub sort: Option<(String, SortDir)>,
    /// Final `$limit`.
    pub limit: Option<usize>,
}

impl AggPipeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn matching(mut self, filter: Filter) -> Self {
        self.filter = filter;
        self
    }

    pub fn project(mut self, fields: &[&str]) -> Self {
        self.project = Some(fields.iter().map(|s| s.to_string()).collect());
        self
    }

    pub fn group_by(mut self, field: &str) -> Self {
        self.group_by = Some(field.to_string());
        self
    }

    pub fn acc(mut self, name: &str, op: AccOp, field: &str) -> Self {
        self.accs.push(AccSpec { name: name.into(), op, field: field.into() });
        self
    }

    pub fn count(self, name: &str) -> Self {
        self.acc(name, AccOp::Count, "")
    }

    pub fn sum(self, name: &str, field: &str) -> Self {
        self.acc(name, AccOp::Sum, field)
    }

    pub fn min(self, name: &str, field: &str) -> Self {
        self.acc(name, AccOp::Min, field)
    }

    pub fn max(self, name: &str, field: &str) -> Self {
        self.acc(name, AccOp::Max, field)
    }

    pub fn avg(self, name: &str, field: &str) -> Self {
        self.acc(name, AccOp::Avg, field)
    }

    pub fn sort(mut self, field: &str, dir: SortDir) -> Self {
        self.sort = Some((field.to_string(), dir));
        self
    }

    pub fn limit(mut self, n: usize) -> Self {
        self.limit = Some(n);
        self
    }

    /// Is `field` visible past the `$project` stage?
    pub fn sees(&self, field: &str) -> bool {
        match &self.project {
            Some(fields) => fields.iter().any(|f| f == field),
            None => true,
        }
    }

    /// The kernel-accumulate shape: `Some((key_field, value_field))`
    /// when the fold can route through the compiled stats kernel — a
    /// visible scalar group key, every accumulator `count`/`min`/`max`,
    /// and all min/max on one shared visible field. `sum`/`avg` stay on
    /// the scalar fold: the stats artifact returns a (f32) mean, and a
    /// partial sum reconstructed from a rounded mean is lossy, while
    /// min/max/count are exact whenever the inputs are (the per-value
    /// losslessness check lives at the fold site in `server/read.rs`).
    pub fn kernel_shape(&self) -> Option<(&str, &str)> {
        let key = self.group_by.as_deref().filter(|k| self.sees(k))?;
        let mut value: Option<&str> = None;
        for spec in &self.accs {
            match spec.op {
                AccOp::Count => {}
                AccOp::Min | AccOp::Max => {
                    if !self.sees(&spec.field) {
                        return None;
                    }
                    match value {
                        None => value = Some(&spec.field),
                        Some(v) if v == spec.field => {}
                        Some(_) => return None,
                    }
                }
                AccOp::Sum | AccOp::Avg => return None,
            }
        }
        value.map(|v| (key, v))
    }

    /// Wire-size estimate for transport accounting (request leg).
    pub fn encoded_len(&self) -> usize {
        self.filter.encoded_len()
            + self.project.iter().flatten().map(|f| 1 + f.len()).sum::<usize>()
            + self.group_by.as_ref().map_or(0, |g| 1 + g.len())
            + self.accs.iter().map(|a| 2 + a.name.len() + a.field.len()).sum::<usize>()
            + self.sort.as_ref().map_or(0, |(f, _)| 2 + f.len())
            + 16
    }

    /// The naive decode-everything reference executor: filter decoded
    /// documents, fold them through the same accumulator algebra, and
    /// finalize. Doubles as the router's central fold for the full-ship
    /// baseline; the distributed raw-probe fold must agree bit-for-bit.
    pub fn execute_docs<'a>(
        &self,
        docs: impl IntoIterator<Item = &'a Document>,
    ) -> Vec<Document> {
        let mut table = PartialTable::new();
        for d in docs {
            if self.filter.matches(d) {
                table.fold_doc(self, d);
            }
        }
        self.finalize(table)
    }

    /// Merge-side terminal: order groups by key, finalize accumulator
    /// states into output documents, then apply `$sort`/`$limit`.
    pub fn finalize(&self, table: PartialTable) -> Vec<Document> {
        let mut out: Vec<Document> = table
            .into_rows()
            .into_iter()
            .map(|row| {
                let mut d = Document::new().set("_id", row.key.to_value());
                for (spec, st) in self.accs.iter().zip(row.accs) {
                    d.put(&spec.name, st.finalize());
                }
                d
            })
            .collect();
        if let Some((field, dir)) = &self.sort {
            // Same comparison posture as the router's k-way document
            // merge: missing sort fields order as null; a stable sort
            // keeps the group-key order on ties.
            out.sort_by(|a, b| {
                let va = a.get(field).unwrap_or(&Value::Null);
                let vb = b.get(field).unwrap_or(&Value::Null);
                let ord = va.cmp_total(vb);
                match dir {
                    SortDir::Asc => ord,
                    SortDir::Desc => ord.reverse(),
                }
            });
        }
        if let Some(n) = self.limit {
            out.truncate(n);
        }
        out
    }
}

/// A group key: the scalar identity a document's `$group` field value
/// hashes and orders by. Container values and missing fields key as
/// [`GroupKey::Null`]; `F64` keys by bit pattern (`f64::total_cmp`
/// order), so equality, hashing, and ordering always agree.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum GroupKey {
    Null,
    Bool(bool),
    Int(i64),
    F64(u64),
    Str(String),
}

impl GroupKey {
    pub fn from_value(v: &Value) -> GroupKey {
        match v {
            Value::Null | Value::Array(_) | Value::Doc(_) => GroupKey::Null,
            Value::Bool(b) => GroupKey::Bool(*b),
            Value::Int(i) => GroupKey::Int(*i),
            Value::F64(f) => GroupKey::F64(f.to_bits()),
            Value::Str(s) => GroupKey::Str(s.clone()),
        }
    }

    pub fn from_raw(v: &RawValue<'_>) -> GroupKey {
        match v {
            RawValue::Null | RawValue::Array(_) | RawValue::Doc(_) => GroupKey::Null,
            RawValue::Bool(b) => GroupKey::Bool(*b),
            RawValue::Int(i) => GroupKey::Int(*i),
            RawValue::F64(f) => GroupKey::F64(f.to_bits()),
            RawValue::Str(s) => GroupKey::Str((*s).to_string()),
        }
    }

    pub fn to_value(&self) -> Value {
        match self {
            GroupKey::Null => Value::Null,
            GroupKey::Bool(b) => Value::Bool(*b),
            GroupKey::Int(i) => Value::Int(*i),
            GroupKey::F64(bits) => Value::F64(f64::from_bits(*bits)),
            GroupKey::Str(s) => Value::Str(s.clone()),
        }
    }

    fn rank(&self) -> u8 {
        match self {
            GroupKey::Null => 0,
            GroupKey::Bool(_) => 1,
            GroupKey::Int(_) => 2,
            GroupKey::F64(_) => 3,
            GroupKey::Str(_) => 4,
        }
    }

    /// Wire-size estimate of the key inside an [`AggRow`].
    fn wire_bytes(&self) -> usize {
        1 + match self {
            GroupKey::Null => 0,
            GroupKey::Bool(_) => 1,
            GroupKey::Int(_) | GroupKey::F64(_) => 8,
            GroupKey::Str(s) => 4 + s.len(),
        }
    }
}

impl Ord for GroupKey {
    fn cmp(&self, other: &Self) -> Ordering {
        let (ra, rb) = (self.rank(), other.rank());
        if ra != rb {
            return ra.cmp(&rb);
        }
        match (self, other) {
            (GroupKey::Bool(a), GroupKey::Bool(b)) => a.cmp(b),
            (GroupKey::Int(a), GroupKey::Int(b)) => a.cmp(b),
            (GroupKey::F64(a), GroupKey::F64(b)) => {
                f64::from_bits(*a).total_cmp(&f64::from_bits(*b))
            }
            (GroupKey::Str(a), GroupKey::Str(b)) => a.cmp(b),
            _ => Ordering::Equal,
        }
    }
}

impl PartialOrd for GroupKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// One accumulator's *partial* state — the thing that crosses the wire
/// and merges. The algebra is closed under merge: merging any split of
/// a document set yields the state of folding it whole.
#[derive(Clone, Debug, PartialEq)]
pub enum AccState {
    Count(u64),
    Sum(f64),
    Min(Option<Value>),
    Max(Option<Value>),
    /// `avg` ships the (sum, count) pair; dividing per shard and
    /// re-averaging would weight shards, not documents.
    Avg { sum: f64, n: u64 },
}

impl AccState {
    pub fn init(op: AccOp) -> AccState {
        match op {
            AccOp::Count => AccState::Count(0),
            AccOp::Sum => AccState::Sum(0.0),
            AccOp::Min => AccState::Min(None),
            AccOp::Max => AccState::Max(None),
            AccOp::Avg => AccState::Avg { sum: 0.0, n: 0 },
        }
    }

    /// Fold one document's field value (None = missing or projected
    /// away). `Count` ignores the value entirely.
    pub fn fold(&mut self, v: Option<&Value>) {
        match self {
            AccState::Count(n) => *n += 1,
            AccState::Sum(s) => {
                if let Some(x) = v.and_then(Value::as_f64) {
                    *s += x;
                }
            }
            AccState::Avg { sum, n } => {
                if let Some(x) = v.and_then(Value::as_f64) {
                    *sum += x;
                    *n += 1;
                }
            }
            AccState::Min(cur) => {
                if let Some(v) = v {
                    let wins = cur
                        .as_ref()
                        .map_or(true, |c| v.cmp_total(c) == Ordering::Less);
                    if wins {
                        *cur = Some(v.clone());
                    }
                }
            }
            AccState::Max(cur) => {
                if let Some(v) = v {
                    let wins = cur
                        .as_ref()
                        .map_or(true, |c| v.cmp_total(c) == Ordering::Greater);
                    if wins {
                        *cur = Some(v.clone());
                    }
                }
            }
        }
    }

    /// Raw-path fold: probes decide via [`RawValue::cmp_total`] and
    /// materialize a value only when it wins the fold.
    pub fn fold_raw(&mut self, v: Option<&RawValue<'_>>) {
        match self {
            AccState::Count(n) => *n += 1,
            AccState::Sum(s) => {
                if let Some(x) = v.and_then(RawValue::as_f64) {
                    *s += x;
                }
            }
            AccState::Avg { sum, n } => {
                if let Some(x) = v.and_then(RawValue::as_f64) {
                    *sum += x;
                    *n += 1;
                }
            }
            AccState::Min(cur) => {
                if let Some(v) = v {
                    let wins = cur
                        .as_ref()
                        .map_or(true, |c| v.cmp_total(c) == Ordering::Less);
                    if wins {
                        if let Some(owned) = v.to_value() {
                            *cur = Some(owned);
                        }
                    }
                }
            }
            AccState::Max(cur) => {
                if let Some(v) = v {
                    let wins = cur
                        .as_ref()
                        .map_or(true, |c| v.cmp_total(c) == Ordering::Greater);
                    if wins {
                        if let Some(owned) = v.to_value() {
                            *cur = Some(owned);
                        }
                    }
                }
            }
        }
    }

    /// Merge another shard's partial state into this one. States of
    /// mismatched kinds (a malformed reply) leave `self` unchanged.
    pub fn merge(&mut self, other: &AccState) {
        match (self, other) {
            (AccState::Count(a), AccState::Count(b)) => *a += b,
            (AccState::Sum(a), AccState::Sum(b)) => *a += b,
            (AccState::Avg { sum, n }, AccState::Avg { sum: s2, n: n2 }) => {
                *sum += s2;
                *n += n2;
            }
            (AccState::Min(a), AccState::Min(b)) => {
                if let Some(bv) = b {
                    let wins = a
                        .as_ref()
                        .map_or(true, |av| bv.cmp_total(av) == Ordering::Less);
                    if wins {
                        *a = Some(bv.clone());
                    }
                }
            }
            (AccState::Max(a), AccState::Max(b)) => {
                if let Some(bv) = b {
                    let wins = a
                        .as_ref()
                        .map_or(true, |av| bv.cmp_total(av) == Ordering::Greater);
                    if wins {
                        *a = Some(bv.clone());
                    }
                }
            }
            _ => {}
        }
    }

    /// Terminal value: this is where `avg` divides — the one lossy step,
    /// deferred past every merge.
    pub fn finalize(self) -> Value {
        match self {
            AccState::Count(n) => Value::Int(n as i64),
            AccState::Sum(s) => Value::F64(s),
            AccState::Min(v) | AccState::Max(v) => v.unwrap_or(Value::Null),
            AccState::Avg { sum, n } => {
                if n == 0 {
                    Value::Null
                } else {
                    Value::F64(sum / n as f64)
                }
            }
        }
    }

    /// Wire-size estimate inside an [`AggRow`].
    fn wire_bytes(&self) -> usize {
        1 + match self {
            AccState::Count(_) | AccState::Sum(_) => 8,
            AccState::Avg { .. } => 16,
            AccState::Min(v) | AccState::Max(v) => match v {
                None => 0,
                Some(Value::Str(s)) => 5 + s.len(),
                Some(_) => 9,
            },
        }
    }
}

/// One group's partial accumulator row — the unit a shard ships.
#[derive(Clone, Debug, PartialEq)]
pub struct AggRow {
    pub key: GroupKey,
    pub accs: Vec<AccState>,
}

impl AggRow {
    /// Wire-size estimate for transport accounting (reply leg).
    pub fn wire_bytes(&self) -> usize {
        self.key.wire_bytes() + self.accs.iter().map(AccState::wire_bytes).sum::<usize>()
    }
}

/// A group → partial-accumulator table: the shard's fold target and the
/// router's merge target.
#[derive(Default)]
pub struct PartialTable {
    groups: HashMap<GroupKey, Vec<AccState>>,
}

impl PartialTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.groups.len()
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }

    fn states_for(&mut self, p: &AggPipeline, key: GroupKey) -> &mut Vec<AccState> {
        self.groups
            .entry(key)
            .or_insert_with(|| p.accs.iter().map(|a| AccState::init(a.op)).collect())
    }

    /// Fold one decoded document (reference executor / full-ship fold).
    pub fn fold_doc(&mut self, p: &AggPipeline, d: &Document) {
        let key = match &p.group_by {
            Some(k) if p.sees(k) => {
                d.get(k).map(GroupKey::from_value).unwrap_or(GroupKey::Null)
            }
            _ => GroupKey::Null,
        };
        let states = self.states_for(p, key);
        for (st, spec) in states.iter_mut().zip(&p.accs) {
            let v = if p.sees(&spec.field) { d.get(&spec.field) } else { None };
            st.fold(v);
        }
    }

    /// Fold one *encoded* record via [`RawDoc`] probes — the shard's
    /// accumulate path. No full decode: each stage seeks only the
    /// fields it names, and min/max materialize a value only on a win.
    pub fn fold_raw(&mut self, p: &AggPipeline, raw: &RawDoc<'_>) {
        let key = match &p.group_by {
            Some(k) if p.sees(k) => {
                raw.get(k).map(|v| GroupKey::from_raw(&v)).unwrap_or(GroupKey::Null)
            }
            _ => GroupKey::Null,
        };
        let states = self.states_for(p, key);
        for (st, spec) in states.iter_mut().zip(&p.accs) {
            let v = if p.sees(&spec.field) { raw.get(&spec.field) } else { None };
            st.fold_raw(v.as_ref());
        }
    }

    /// Install a fully-built group row (the kernel accumulate path
    /// constructs states from column reductions).
    pub fn insert_group(&mut self, key: GroupKey, states: Vec<AccState>) {
        self.groups.insert(key, states);
    }

    /// Kernel-path bail-out: replay one gathered `(Int key, F64 value)`
    /// column pair through the scalar fold. Only meaningful for
    /// kernel-shaped pipelines (every accumulator is count/min/max on
    /// the one gathered field), where it reproduces exactly the states
    /// [`Self::fold_raw`] would have built for that record.
    pub fn fold_kernel_pair(&mut self, p: &AggPipeline, key: i64, value: f64) {
        let v = Value::F64(value);
        let states = self.states_for(p, GroupKey::Int(key));
        for st in states.iter_mut() {
            st.fold(Some(&v));
        }
    }

    /// Merge one shard's partial rows (router side).
    pub fn merge_rows(&mut self, p: &AggPipeline, rows: Vec<AggRow>) {
        for row in rows {
            let states = self.states_for(p, row.key);
            for (st, other) in states.iter_mut().zip(&row.accs) {
                st.merge(other);
            }
        }
    }

    /// Drain into rows ordered by the group-key total order — the
    /// deterministic base order `$sort` ties preserve.
    pub fn into_rows(self) -> Vec<AggRow> {
        let mut rows: Vec<AggRow> = self
            .groups
            .into_iter()
            .map(|(key, accs)| AggRow { key, accs })
            .collect();
        rows.sort_by(|a, b| a.key.cmp(&b.key));
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mongo::query::CmpOp;
    use crate::testing::check_with;
    use crate::util::rng::Pcg32;

    fn doc(ts: i64, node: i64, load: f64) -> Document {
        Document::new().set("ts", ts).set("node_id", node).set("load", load)
    }

    fn window_rollup() -> AggPipeline {
        AggPipeline::new()
            .matching(Filter::range("ts", 10i64, 40i64))
            .group_by("node_id")
            .count("n")
            .sum("total", "load")
            .min("lo", "load")
            .max("hi", "load")
            .avg("mean", "load")
    }

    #[test]
    fn reference_executor_groups_and_accumulates() {
        let docs: Vec<Document> = vec![
            doc(10, 1, 2.0),
            doc(20, 1, 4.0),
            doc(30, 2, 8.0),
            doc(50, 1, 100.0), // outside the window
        ];
        let rows = window_rollup().execute_docs(&docs);
        assert_eq!(rows.len(), 2);
        let g1 = &rows[0];
        assert_eq!(g1.get_i64("_id"), Some(1));
        assert_eq!(g1.get_i64("n"), Some(2));
        assert_eq!(g1.get_f64("total"), Some(6.0));
        assert_eq!(g1.get_f64("lo"), Some(2.0));
        assert_eq!(g1.get_f64("hi"), Some(4.0));
        assert_eq!(g1.get_f64("mean"), Some(3.0));
        let g2 = &rows[1];
        assert_eq!(g2.get_i64("_id"), Some(2));
        assert_eq!(g2.get_i64("n"), Some(1));
        assert_eq!(g2.get_f64("mean"), Some(8.0));
    }

    #[test]
    fn sort_and_limit_apply_after_finalize() {
        let docs: Vec<Document> =
            (0..12).map(|i| doc(i, i % 4, (i % 4) as f64)).collect();
        let p = AggPipeline::new()
            .group_by("node_id")
            .count("n")
            .avg("mean", "load")
            .sort("mean", SortDir::Desc)
            .limit(2);
        let rows = p.execute_docs(&docs);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get_i64("_id"), Some(3));
        assert_eq!(rows[1].get_i64("_id"), Some(2));
    }

    #[test]
    fn project_hides_fields_from_group_and_accumulate() {
        let docs = vec![doc(1, 1, 5.0), doc(2, 2, 7.0)];
        let p = AggPipeline::new()
            .project(&["ts"])
            .group_by("node_id") // projected away -> one null group
            .count("n")
            .sum("s", "load"); // projected away -> 0.0
        let rows = p.execute_docs(&docs);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("_id"), Some(&Value::Null));
        assert_eq!(rows[0].get_i64("n"), Some(2));
        assert_eq!(rows[0].get_f64("s"), Some(0.0));
    }

    #[test]
    fn missing_and_nonnumeric_field_semantics() {
        let docs = vec![
            Document::new().set("node_id", 1i64).set("v", 3i64),
            Document::new().set("node_id", 1i64).set("v", "text"),
            Document::new().set("node_id", 1i64), // v missing
        ];
        let p = AggPipeline::new()
            .group_by("node_id")
            .count("n")
            .sum("s", "v")
            .min("lo", "v")
            .max("hi", "v")
            .avg("mean", "v");
        let rows = p.execute_docs(&docs);
        assert_eq!(rows[0].get_i64("n"), Some(3));
        // sum/avg: only the numeric value contributes.
        assert_eq!(rows[0].get_f64("s"), Some(3.0));
        assert_eq!(rows[0].get_f64("mean"), Some(3.0));
        // min/max fold any present value under the total order
        // (numbers < strings).
        assert_eq!(rows[0].get("lo"), Some(&Value::Int(3)));
        assert_eq!(rows[0].get("hi"), Some(&Value::Str("text".into())));
        // Empty group: sum is 0.0, min/max/avg are null.
        let empty = AggPipeline::new().sum("s", "v").min("lo", "v").avg("a", "v");
        let rows = empty.execute_docs(&[] as &[Document]);
        assert_eq!(rows.len(), 0, "no documents -> no groups");
    }

    #[test]
    fn avg_must_finalize_at_merge_not_per_shard() {
        // Shard A holds one doc (v=0), shard B holds three (v=4 each):
        // mean of per-shard means would be 2.0; the true mean is 3.0.
        let p = AggPipeline::new().avg("mean", "v");
        let a = vec![Document::new().set("v", 0i64)];
        let b: Vec<Document> = (0..3).map(|_| Document::new().set("v", 4i64)).collect();
        let mut ta = PartialTable::new();
        for d in &a {
            ta.fold_doc(&p, d);
        }
        let mut tb = PartialTable::new();
        for d in &b {
            tb.fold_doc(&p, d);
        }
        let mut merged = PartialTable::new();
        merged.merge_rows(&p, ta.into_rows());
        merged.merge_rows(&p, tb.into_rows());
        let rows = p.finalize(merged);
        assert_eq!(rows[0].get_f64("mean"), Some(3.0));
    }

    #[test]
    fn group_keys_order_hash_and_roundtrip_consistently() {
        let vals = [
            Value::Null,
            Value::Bool(false),
            Value::Bool(true),
            Value::Int(-3),
            Value::Int(7),
            Value::F64(-0.5),
            Value::F64(2.25),
            Value::Str("a".into()),
            Value::Str("b".into()),
        ];
        let keys: Vec<GroupKey> = vals.iter().map(GroupKey::from_value).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(sorted, keys, "construction order above is the total order");
        for (v, k) in vals.iter().zip(&keys) {
            assert_eq!(&k.to_value(), v);
            // Raw and decoded construction agree.
            let enc = Document::new().set("k", v.clone()).encode();
            let raw = RawDoc::new(&enc);
            assert_eq!(&GroupKey::from_raw(&raw.get("k").unwrap()), k);
        }
        // Containers key as null.
        assert_eq!(
            GroupKey::from_value(&Value::Array(vec![Value::Int(1)])),
            GroupKey::Null
        );
    }

    #[test]
    fn kernel_shape_gate() {
        let ok = AggPipeline::new()
            .group_by("node_id")
            .count("n")
            .min("lo", "load")
            .max("hi", "load");
        assert_eq!(ok.kernel_shape(), Some(("node_id", "load")));
        // sum/avg exclude the kernel path (lossy mean->sum).
        assert!(window_rollup().kernel_shape().is_none());
        // Two distinct min/max fields exclude it.
        let two = AggPipeline::new().group_by("n").min("a", "x").max("b", "y");
        assert!(two.kernel_shape().is_none());
        // No group key, or a projected-away one, excludes it.
        assert!(AggPipeline::new().min("a", "x").kernel_shape().is_none());
        let hidden = AggPipeline::new().project(&["x"]).group_by("n").min("a", "x");
        assert!(hidden.kernel_shape().is_none());
        // Count-only pipelines have no column to reduce.
        assert!(AggPipeline::new().group_by("n").count("c").kernel_shape().is_none());
    }

    /// Exact-in-f64 random values: integers and quarter fractions keep
    /// every sum order-independent, so the distributed fold (per-shard
    /// partials merged in shard order) is bit-identical to the central
    /// fold.
    fn rand_metric(rng: &mut Pcg32) -> Value {
        match rng.next_bounded(3) {
            0 => Value::Int(rng.next_bounded(64) as i64 - 32),
            1 => Value::F64((rng.next_bounded(257) as f64 - 128.0) * 0.25),
            _ => Value::Null,
        }
    }

    fn rand_corpus_doc(rng: &mut Pcg32) -> Document {
        let mut d = Document::new();
        if rng.next_bounded(8) > 0 {
            d.put("ts", Value::Int(rng.next_bounded(100) as i64));
        }
        if rng.next_bounded(8) > 0 {
            d.put("node_id", Value::Int(rng.next_bounded(6) as i64));
        }
        if rng.next_bounded(4) > 0 {
            d.put("load", rand_metric(rng));
        }
        if rng.next_bounded(4) == 0 {
            d.put("tag", Value::Str(format!("t{}", rng.next_bounded(3))));
        }
        d
    }

    fn rand_pipeline(rng: &mut Pcg32) -> AggPipeline {
        const FIELDS: [&str; 4] = ["ts", "node_id", "load", "tag"];
        let field = |rng: &mut Pcg32| FIELDS[rng.next_bounded(4) as usize];
        let mut p = AggPipeline::new();
        p = match rng.next_bounded(4) {
            0 => p,
            1 => p.matching(Filter::range(
                "ts",
                rng.next_bounded(50) as i64,
                (50 + rng.next_bounded(60)) as i64,
            )),
            2 => p.matching(Filter::cmp(
                field(rng),
                CmpOp::Gte,
                Value::Int(rng.next_bounded(40) as i64 - 20),
            )),
            _ => p.matching(Filter::is_in(
                "node_id",
                (0..1 + rng.next_bounded(3)).map(|i| Value::Int(i as i64)).collect(),
            )),
        };
        if rng.next_bounded(4) == 0 {
            let keep: Vec<&str> =
                FIELDS.iter().copied().filter(|_| rng.next_bounded(2) == 0).collect();
            p = p.project(&keep);
        }
        if rng.next_bounded(5) > 0 {
            p = p.group_by(field(rng));
        }
        for i in 0..1 + rng.next_bounded(4) {
            let f = field(rng);
            p = match rng.next_bounded(5) {
                0 => p.count(&format!("a{i}")),
                1 => p.sum(&format!("a{i}"), f),
                2 => p.min(&format!("a{i}"), f),
                3 => p.max(&format!("a{i}"), f),
                _ => p.avg(&format!("a{i}"), f),
            };
        }
        if rng.next_bounded(2) == 0 {
            let by = if rng.next_bounded(2) == 0 { "_id" } else { "a0" };
            let dir = if rng.next_bounded(2) == 0 { SortDir::Asc } else { SortDir::Desc };
            p = p.sort(by, dir);
        }
        if rng.next_bounded(3) == 0 {
            p = p.limit(1 + rng.next_bounded(5) as usize);
        }
        p
    }

    /// The tentpole differential: a random corpus partitioned over k
    /// simulated shards, folded per shard over *encoded bytes* with the
    /// raw-probe path, merged in shard order, and finalized — must be
    /// bit-identical to the naive decode-everything reference executor
    /// over the whole corpus.
    #[test]
    fn sharded_fold_agrees_with_reference() {
        check_with(
            "agg-sharded-differential",
            0xA66,
            256,
            &(|rng: &mut Pcg32| {
                let docs: Vec<Document> =
                    (0..rng.next_bounded(60)).map(|_| rand_corpus_doc(rng)).collect();
                let shards = 1 + rng.next_bounded(4) as usize;
                let pipeline = rand_pipeline(rng);
                (docs, shards, pipeline)
            }),
            |(docs, shards, pipeline)| {
                let reference = pipeline.execute_docs(docs.iter());

                // Distribute round-robin, fold each shard over encoded
                // bytes, merge partials in shard order.
                let mut merged = PartialTable::new();
                let mut shipped_rows = 0usize;
                for s in 0..*shards {
                    let mut t = PartialTable::new();
                    for d in docs.iter().skip(s).step_by(*shards) {
                        let enc = d.encode();
                        let raw = RawDoc::new(&enc);
                        if pipeline.filter.matches_raw(&raw) {
                            t.fold_raw(pipeline, &raw);
                        }
                    }
                    let rows = t.into_rows();
                    shipped_rows += rows.len();
                    merged.merge_rows(pipeline, rows);
                }
                let distributed = pipeline.finalize(merged);

                if distributed != reference {
                    return Err(format!(
                        "distributed {distributed:?} != reference {reference:?}"
                    ));
                }
                // The partial reply is O(groups): each shard ships at
                // most one row per distinct group key.
                let matched: Vec<&Document> =
                    docs.iter().filter(|d| pipeline.filter.matches(d)).collect();
                let groups: std::collections::HashSet<GroupKey> = matched
                    .iter()
                    .map(|d| match &pipeline.group_by {
                        Some(k) if pipeline.sees(k) => d
                            .get(k)
                            .map(GroupKey::from_value)
                            .unwrap_or(GroupKey::Null),
                        _ => GroupKey::Null,
                    })
                    .collect();
                if shipped_rows > groups.len() * *shards {
                    return Err(format!(
                        "shipped {shipped_rows} rows > groups {} x shards {shards}",
                        groups.len()
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn merge_is_associative_over_random_splits() {
        check_with(
            "agg-merge-associative",
            0x51AB,
            128,
            &(|rng: &mut Pcg32| {
                let docs: Vec<Document> =
                    (0..1 + rng.next_bounded(40)).map(|_| rand_corpus_doc(rng)).collect();
                let cut = rng.next_bounded(docs.len() as u32) as usize;
                (docs, cut)
            }),
            |(docs, cut)| {
                let p = AggPipeline::new()
                    .group_by("node_id")
                    .count("n")
                    .sum("s", "load")
                    .min("lo", "load")
                    .max("hi", "load")
                    .avg("m", "load");
                let whole = p.execute_docs(docs.iter());
                let mut left = PartialTable::new();
                for d in &docs[..*cut] {
                    left.fold_doc(&p, d);
                }
                let mut right = PartialTable::new();
                for d in &docs[*cut..] {
                    right.fold_doc(&p, d);
                }
                let mut merged = PartialTable::new();
                merged.merge_rows(&p, left.into_rows());
                merged.merge_rows(&p, right.into_rows());
                let split = p.finalize(merged);
                if split == whole {
                    Ok(())
                } else {
                    Err(format!("split {split:?} != whole {whole:?}"))
                }
            },
        );
    }
}
