//! Snapshot-pinned read path + reader pool.
//!
//! Every `Find`/`GetMore`/`Count` executes against a [`ReadView`] — an
//! MVCC snapshot of the shard's store pinned at the committed epoch
//! (docs/ARCHITECTURE.md §9). The planner, streaming cursors, kernel
//! fast path, and raw matcher all moved here from `shard.rs`,
//! parameterized over the view, so the same code serves two dispatch
//! modes:
//!
//! * `--reader-threads 0` (default): the shard event loop calls
//!   [`ReadContext::serve`] inline — single-threaded, exactly the old
//!   behaviour, but already snapshot-isolated.
//! * `--reader-threads N`: the event loop forwards read requests to a
//!   [`ReaderPool`] of N threads and immediately returns to ingest /
//!   checkpoint / migration work. Readers never block the writer: a
//!   view holds the store's `RwLock` read-side only for one bounded
//!   batch (`SCAN_RUN` candidates / one reply batch).
//!
//! Open cursors pin their snapshot in the shared [`ReadContext`]
//! registry; a `GetMore` re-pins the *same* epoch, so a cursor drains a
//! frozen result set no matter how far ingest, range deletes, or a
//! chunk-migration publish have advanced — or fails with the retryable
//! [`WireError::SnapshotExpired`] once the retention knob reclaims its
//! epoch. Mailbox ordering gives read-your-writes: a find forwarded
//! after an insert batch commits pins an epoch at or past that commit.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Instant;

use crate::metrics::{names, Registry};
use crate::mongo::aggregate::{AccOp, AccState, AggPipeline, GroupKey, PartialTable};
use crate::mongo::bson::{Document, RawDoc, RawValue, Value};
use crate::mongo::query::{Filter, FindOptions, SortDir};
use crate::mongo::sharding::chunk::ShardKey;
use crate::mongo::storage::index::{encode_key, EncodedRange, Index};
use crate::mongo::storage::{ReadView, RecordId, Snapshot, SnapshotExpired, StoreReader};
use crate::mongo::wire::{AggregateReply, CountReply, FindReply, Reply, WireError};
use crate::runtime::Kernels;

use super::shard::COLLECTION;

/// Index names the planner recognizes.
const COMPOUND_INDEX: &str = "node_id_1_ts_1";
const TS_INDEX: &str = "ts_1";
const NODE_INDEX: &str = "node_id_1";

/// Keys/rids pulled into a streaming cursor per refill step — bounds
/// the work done under one store read guard without per-key round
/// trips.
const SCAN_RUN: usize = 256;

/// Read requests a shard dispatches off its event loop. Mirrors the
/// read subset of `ShardRequest`; the writer forwards the reply sender
/// so the pool answers clients directly.
pub enum ReadRequest {
    Find {
        filter: Filter,
        opts: FindOptions,
        reply: Reply<Result<FindReply, WireError>>,
    },
    GetMore {
        cursor: u64,
        reply: Reply<Result<FindReply, WireError>>,
    },
    Count {
        filter: Filter,
        reply: Reply<Result<CountReply, WireError>>,
    },
    /// Aggregation leg: fold matches into per-group partial accumulators
    /// over raw bytes (`partial`), or decode and ship every match for
    /// the router's central fold (the full-ship baseline).
    Aggregate {
        pipeline: AggPipeline,
        partial: bool,
        reply: Reply<Result<AggregateReply, WireError>>,
    },
}

/// Orphan fence: what this shard's readers must *not* serve while a
/// chunk migration's copies are in motion (docs/ARCHITECTURE.md §6.3).
/// The shard event loop updates the shared fence when it processes a
/// `SetMap` or publishes a staged chunk; every read copies the fence
/// *before* pinning its snapshot (so a fence naming a published handoff
/// is always paired with a snapshot that already contains the published
/// documents) and a cursor freezes its copy for its whole drain.
///
/// Both filters default to `None` — the fence costs two `Option` checks
/// per request outside migration windows.
///
/// `PartialEq` backs the fence/pin stability check in
/// [`ReadContext::pin_with_fence`]: a read's fence copy and snapshot
/// are only used together once the fence reads identically on both
/// sides of the pin.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReadFence {
    /// Chunk-map version the fence reflects; `Count` replies carry it
    /// so the router can insist on a version-uniform scatter.
    pub version: u64,
    /// Shard key for position extraction (present iff `exclude_range`
    /// is).
    pub key: Option<ShardKey>,
    /// Donor-side orphan filter: the map shows a *published* handoff
    /// naming this shard as donor — live documents whose shard-key
    /// position falls in this inclusive range are duplicates of the
    /// destination's published copies and must be dropped.
    pub exclude_range: Option<(u64, u64)>,
    /// Destination-side mask: the contiguous record-id run a
    /// `PublishStaged` made live *before* this shard processed the map
    /// version that marks the handoff published. Until that map
    /// arrives, the donor's copies are still what the cluster counts —
    /// serving these rids too would double-count the range.
    pub mask_rids: Option<(RecordId, RecordId)>,
}

impl ReadFence {
    #[inline]
    fn active(&self) -> bool {
        self.exclude_range.is_some() || self.mask_rids.is_some()
    }

    /// Must `rid` (with record bytes `raw`) be hidden from this read?
    fn excludes(&self, rid: RecordId, raw: &[u8]) -> bool {
        if let Some((lo, hi)) = self.mask_rids {
            if lo <= rid && rid <= hi {
                return true;
            }
        }
        if let (Some(key), Some((lo, hi))) = (self.key.as_ref(), self.exclude_range) {
            let d = RawDoc::new(raw);
            if let (Some(node), Some(ts)) = (d.get_i64("node_id"), d.get_i64("ts")) {
                // Same negative-value clamp as every other position
                // site (`ShardKey::position_i64`): the shard fence and
                // the router fence must classify a document
                // identically.
                let pos = key.position_i64(node, ts);
                if lo <= pos && pos <= hi {
                    return true;
                }
            }
        }
        false
    }
}

/// One access path chosen by the planner.
enum ScanPlan {
    /// Materialized candidate rids (the index-intersection fallback and
    /// point-lookup plans); the residual matcher still runs.
    Rids(Vec<RecordId>),
    /// Resumable scan over `index`: encoded `[lo, hi)` ranges walked in
    /// order, yielding rids in index-key order. `rev` walks each range
    /// descending (the builder orders `ranges` to match the overall
    /// direction; every `rev` plan today is single-range).
    Index { index: String, ranges: Vec<EncodedRange>, rev: bool },
    /// Resumable full-collection scan in record-id order.
    Table,
}

/// A streaming scan position: plan + residual filter + resume state.
/// The position is a *key* (or record id), not an iterator, so the
/// cursor survives between getMores without borrowing the store; the
/// pinned snapshot keeps the result set frozen regardless.
struct ScanCursor {
    plan: ScanPlan,
    /// Residual filter, evaluated raw per candidate.
    filter: Filter,
    /// Orphan fence frozen when the scan was built (migration windows).
    fence: ReadFence,
    /// Current range within an `Index` plan.
    range_idx: usize,
    /// Last fully consumed key (`Index` plans) — the resume point.
    after_key: Option<Vec<u8>>,
    /// Last consumed record id (`Table` plans).
    after_rid: Option<RecordId>,
    /// Consumed prefix of a `Rids` plan.
    pos: usize,
    /// Candidates pulled from the plan, awaiting the matcher.
    pending: VecDeque<RecordId>,
    /// The underlying scan is exhausted (pending may still hold rids).
    done: bool,
    /// Candidates examined / matched / fence-dropped since the last
    /// metrics flush — batched locally so the hot loop takes no
    /// registry locks.
    seen: u64,
    matched: u64,
    orphans: u64,
}

impl ScanCursor {
    fn new(plan: ScanPlan, filter: Filter, fence: ReadFence) -> Self {
        Self {
            plan,
            filter,
            fence,
            range_idx: 0,
            after_key: None,
            after_rid: None,
            pos: 0,
            pending: VecDeque::new(),
            done: false,
            seen: 0,
            matched: 0,
            orphans: 0,
        }
    }
}

/// Where an open cursor's documents come from.
enum CursorSource {
    /// Matched rids known up front (the kernel fast path).
    Rids { rids: Vec<RecordId>, pos: usize },
    /// Documents materialized at plan time (non-indexed sort fallback:
    /// decoded once, sorted, projected, served from memory).
    Docs { buf: VecDeque<Document> },
    /// Streaming: candidates pulled lazily from a resumable scan,
    /// raw-matched, decoded only when served.
    Scan(ScanCursor),
}

struct CursorState {
    src: CursorSource,
    projection: Option<Vec<String>>,
    batch: usize,
    remaining: Option<usize>,
}

/// An open cursor: its position plus the snapshot pin that freezes its
/// result set. Dropping the entry releases the pin (and, eventually,
/// the dead versions it held back).
struct CursorEntry {
    cur: CursorState,
    snap: Snapshot,
}

/// Decode one raw record for the reply — the read path's only full
/// materialization (projections decode just the projected fields). The
/// caller counts it into `shard.find_decodes`. A record that fails to
/// decode surfaces as a server error instead of killing the serving
/// thread: the engine's bytes are validated on every write and replay,
/// so reaching the error arm means on-disk or in-memory corruption the
/// client deserves to hear about.
fn materialize(raw: &[u8], projection: Option<&[String]>) -> Result<Document, WireError> {
    let rd = RawDoc::new(raw);
    match projection {
        Some(fields) => Ok(rd.project(fields)),
        None => rd
            .decode()
            .map_err(|e| WireError::Server(format!("corrupt record: {e}"))),
    }
}

fn cursor_exhausted(cur: &CursorState) -> bool {
    match &cur.src {
        CursorSource::Rids { rids, pos } => *pos >= rids.len(),
        CursorSource::Docs { buf } => buf.is_empty(),
        CursorSource::Scan(scan) => scan.done && scan.pending.is_empty(),
    }
}

/// The paper's canonical query shape, *exactly*: a conjunction of
/// `ts >= lo` (`$gte`), `ts < hi` (`$lt`) and `node_id $in [ints]` and
/// nothing else — the only shape the filter kernel's predicate
/// `lo <= ts < hi && node in set` evaluates completely. Any other
/// filter takes the scalar matcher path.
fn canonical_shape(filter: &Filter) -> Option<(u32, u32, Vec<u32>)> {
    use crate::mongo::query::CmpOp;
    let conjuncts = match filter {
        Filter::And(fs) => fs.as_slice(),
        f @ Filter::In { .. } => std::slice::from_ref(f),
        _ => return None,
    };
    let mut lo: Option<u32> = None;
    let mut hi: Option<u32> = None;
    let mut nodes: Option<Vec<u32>> = None;
    for c in conjuncts {
        match c {
            Filter::Cmp { field, op: CmpOp::Gte, value } if field == "ts" && lo.is_none() => {
                let v = value.as_i64()?;
                if !(0..=u32::MAX as i64).contains(&v) {
                    return None;
                }
                lo = Some(v as u32);
            }
            Filter::Cmp { field, op: CmpOp::Lt, value } if field == "ts" && hi.is_none() => {
                let v = value.as_i64()?;
                if !(0..=u32::MAX as i64).contains(&v) {
                    return None;
                }
                hi = Some(v as u32);
            }
            Filter::In { field, values } if field == "node_id" && nodes.is_none() => {
                let mut ids = Vec::with_capacity(values.len());
                for v in values {
                    let n = v.as_i64()?;
                    if !(0..=u32::MAX as i64).contains(&n) {
                        return None;
                    }
                    ids.push(n as u32);
                }
                nodes = Some(ids);
            }
            _ => return None, // anything else → matcher path
        }
    }
    Some((lo.unwrap_or(0), hi.unwrap_or(u32::MAX), nodes?))
}

/// Poison-recovering mutex lock: a reader thread that panicked mid-
/// serve must not wedge every other reader (the shared state — cursor
/// registry, pool queue — stays structurally valid; the panicking
/// request's cursor is simply gone).
fn locked<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

fn expired(e: SnapshotExpired) -> WireError {
    WireError::SnapshotExpired { at: e.at, floor: e.floor }
}

/// Shared, thread-safe read state of one shard: the snapshot source,
/// the kernel handle, and the snapshot-pinning cursor registry. The
/// shard event loop and every reader-pool worker hold the same
/// `Arc<ReadContext>`.
pub struct ReadContext {
    reader: StoreReader,
    kernels: Kernels,
    metrics: Registry,
    default_batch: usize,
    cursors: Mutex<HashMap<u64, CursorEntry>>,
    next_cursor: AtomicU64,
    /// Shared orphan fence (see [`ReadFence`]); written by the shard
    /// event loop, copied by every read before it pins its snapshot.
    fence: Mutex<ReadFence>,
}

impl ReadContext {
    pub fn new(
        reader: StoreReader,
        kernels: Kernels,
        metrics: Registry,
        default_batch: usize,
    ) -> Self {
        Self {
            reader,
            kernels,
            metrics,
            default_batch,
            cursors: Mutex::new(HashMap::new()),
            next_cursor: AtomicU64::new(1),
            fence: Mutex::new(ReadFence::default()),
        }
    }

    /// Cursors currently open (each pins one snapshot).
    pub fn open_cursors(&self) -> usize {
        locked(&self.cursors).len()
    }

    /// Replace the orphan fence (shard event loop, on `SetMap` or a
    /// staged-chunk publish). Reads started after this call observe the
    /// new fence; cursors already open keep their frozen copy, which is
    /// consistent with their frozen snapshot.
    pub fn set_fence(&self, fence: ReadFence) {
        *locked(&self.fence) = fence;
    }

    /// Copy of the current fence.
    pub fn fence(&self) -> ReadFence {
        *locked(&self.fence)
    }

    /// Pin a snapshot paired with a fence copy that is **stable across
    /// the pin**: copy the fence, pin, re-read, and retry the pin until
    /// the fence did not move in between (a seqlock read). The pairing
    /// is what the fence's correctness rests on — the publish path
    /// installs its rid mask *before* the staged documents become
    /// visible to a fresh snapshot, so any snapshot that already
    /// contains a freshly published run can only leave this function
    /// paired with a fence that masks it. Without the re-check, a
    /// reader could copy a mask-less fence just ahead of the publish,
    /// then pin a snapshot containing the published documents and serve
    /// them unmasked under the pre-publish map version — transiently
    /// double-counting the range against the donor's still-live copies
    /// while passing the router's version-uniform check.
    ///
    /// Fence changes are migration-rate events, so the retry loop
    /// settles immediately outside a publish/SetMap instant.
    fn pin_with_fence(&self) -> (ReadFence, Snapshot) {
        let mut fence = self.fence();
        loop {
            let snap = self.reader.snapshot();
            let now = self.fence();
            if now == fence {
                return (fence, snap);
            }
            // Fence moved mid-pin: the snapshot unpins on drop, the
            // fresh fence copy governs the next attempt.
            fence = now;
        }
    }

    /// Execute one read request and answer its reply channel. Called by
    /// pool workers and — with `--reader-threads 0` — inline by the
    /// shard event loop; request latency lands in the same histograms
    /// either way.
    pub fn serve(&self, req: ReadRequest) {
        match req {
            ReadRequest::Find { filter, opts, reply } => {
                let t = Instant::now();
                let r = self.handle_find(&filter, &opts);
                self.metrics
                    .observe(names::SHARD_FIND_NS, t.elapsed().as_nanos() as u64);
                let _ = reply.send(r);
            }
            ReadRequest::GetMore { cursor, reply } => {
                let _ = reply.send(self.handle_get_more(cursor));
            }
            ReadRequest::Count { filter, reply } => {
                let t = Instant::now();
                let r = self.handle_count(&filter);
                self.metrics
                    .observe(names::SHARD_COUNT_NS, t.elapsed().as_nanos() as u64);
                let _ = reply.send(r);
            }
            ReadRequest::Aggregate { pipeline, partial, reply } => {
                let t = Instant::now();
                let r = self.handle_aggregate(&pipeline, partial);
                self.metrics
                    .observe(names::SHARD_AGG_NS, t.elapsed().as_nanos() as u64);
                let _ = reply.send(r);
            }
        }
    }

    pub fn handle_find(
        &self,
        filter: &Filter,
        opts: &FindOptions,
    ) -> Result<FindReply, WireError> {
        self.metrics.counter(names::SHARD_SNAPSHOT_READS).inc();
        // Fence and snapshot pinned as a stable pair: if the fence
        // names a published handoff, the publish committed before the
        // fence was set, so the snapshot already contains the published
        // documents the fence's filtering presumes — and the seqlock
        // re-check guarantees the converse pairing for the publish
        // mask (see `pin_with_fence`).
        let (fence, snap) = self.pin_with_fence();
        // A freshly pinned snapshot sits at the committed epoch; it can
        // only be below the floor if the writer advanced retention-many
        // epochs between the pin and this view — handled like any other
        // expiry: clean retryable error.
        let view = self.reader.view(&snap).map_err(expired)?;
        let src = self.plan_source(&view, filter, opts, fence)?;
        let batch = opts.batch_size.unwrap_or(self.default_batch);
        let mut cur = CursorState {
            src,
            projection: opts.projection.clone(),
            batch,
            remaining: opts.limit,
        };
        let reply = self.serve_batch(&view, &mut cur)?;
        drop(view);
        if reply.cursor.is_some() {
            let id = self.next_cursor.fetch_add(1, Ordering::Relaxed);
            locked(&self.cursors).insert(id, CursorEntry { cur, snap });
            Ok(FindReply { docs: reply.docs, cursor: Some(id) })
        } else {
            // One-batch result: the snapshot unpins right here.
            Ok(reply)
        }
    }

    pub fn handle_get_more(&self, cursor: u64) -> Result<FindReply, WireError> {
        self.metrics.counter(names::SHARD_SNAPSHOT_READS).inc();
        // Remove-serve-reinsert doubles as mutual exclusion: two
        // concurrent getMores on one cursor id cannot interleave batch
        // state — the second sees UnknownCursor, like a drained cursor.
        let CursorEntry { mut cur, snap } = locked(&self.cursors)
            .remove(&cursor)
            .ok_or(WireError::UnknownCursor(cursor))?;
        let view = match self.reader.view(&snap) {
            Ok(v) => v,
            // The retention knob reclaimed this cursor's epoch while it
            // idled: the cursor dies (snap unpins on drop) and the
            // client retries with a fresh find.
            Err(e) => return Err(expired(e)),
        };
        let mut reply = self.serve_batch(&view, &mut cur)?;
        drop(view);
        if reply.cursor.is_some() {
            locked(&self.cursors).insert(cursor, CursorEntry { cur, snap });
            reply.cursor = Some(cursor);
        }
        Ok(reply)
    }

    /// Count without materializing documents for the client. The
    /// canonical shape runs the kernel over raw-extracted columns; any
    /// other filter streams the plan through the raw matcher — counting
    /// decodes nothing at all.
    pub fn handle_count(&self, filter: &Filter) -> Result<CountReply, WireError> {
        self.metrics.counter(names::SHARD_SNAPSHOT_READS).inc();
        // Fence/snapshot pinned as a stable pair — same argument as in
        // [`Self::handle_find`]. The fence's map version travels in the
        // reply for the router's uniform-version retry.
        let (fence, snap) = self.pin_with_fence();
        let view = self.reader.view(&snap).map_err(expired)?;
        // Counts examine candidates exactly like finds do, so both
        // branches publish the candidate/match tallies — the ratio the
        // planner regressions read covers finds and counts alike.
        if let Some((lo, hi, nodes)) = canonical_shape(filter) {
            let words = self.kernels.shapes().filter_w;
            let max_node = nodes.iter().max().copied().unwrap_or(0);
            if (max_node as usize) < words * 32 && !nodes.is_empty() {
                let candidates = self.drain_plan(&view, self.plan_scan(&view, filter));
                self.metrics
                    .counter(names::SHARD_FIND_CANDIDATES)
                    .add(candidates.len() as u64);
                let n =
                    self.kernel_filter(&view, &candidates, lo, hi, &nodes, &fence)?.len() as u64;
                self.metrics.counter(names::SHARD_FIND_MATCHES).add(n);
                return Ok(CountReply { n, version: fence.version });
            }
        }
        let mut scan = ScanCursor::new(self.plan_scan(&view, filter), filter.clone(), fence);
        let mut n = 0u64;
        while self.next_scan_match(&view, &mut scan).is_some() {
            n += 1;
        }
        self.flush_scan_metrics(&mut scan);
        Ok(CountReply { n, version: fence.version })
    }

    /// Execute one aggregation leg over a pinned snapshot
    /// (docs/ARCHITECTURE.md §7.4). The partial push-down path streams
    /// the planned `$match` scan through the raw matcher and folds each
    /// match into per-group accumulators straight off the encoded bytes
    /// — no document decode, so `shard.find_decodes` stays flat — and
    /// ships the O(groups) table. The full-ship baseline
    /// (`--agg-partial 0`) decodes every match for the router's central
    /// fold, which is exactly the traffic the push-down exists to kill.
    pub fn handle_aggregate(
        &self,
        pipeline: &AggPipeline,
        partial: bool,
    ) -> Result<AggregateReply, WireError> {
        self.metrics.counter(names::SHARD_SNAPSHOT_READS).inc();
        // Fence/snapshot pinned as a stable pair — same argument as in
        // [`Self::handle_find`]; the fence's map version travels in the
        // reply for the router's uniform-version retry.
        let (fence, snap) = self.pin_with_fence();
        let view = self.reader.view(&snap).map_err(expired)?;
        let mut scan = ScanCursor::new(
            self.plan_scan(&view, &pipeline.filter),
            pipeline.filter.clone(),
            fence,
        );
        if !partial {
            let mut docs = Vec::new();
            while let Some((_, raw)) = self.next_scan_match(&view, &mut scan) {
                docs.push(
                    RawDoc::new(raw)
                        .decode()
                        .map_err(|e| WireError::Server(format!("corrupt record: {e}")))?,
                );
            }
            self.metrics.counter(names::SHARD_FIND_DECODES).add(docs.len() as u64);
            self.metrics.counter(names::SHARD_AGG_DOCS).add(docs.len() as u64);
            self.flush_scan_metrics(&mut scan);
            return Ok(AggregateReply { rows: Vec::new(), docs, version: fence.version });
        }
        let mut table = PartialTable::new();
        let mut folded = 0u64;
        let kernel_shape = pipeline
            .kernel_shape()
            .filter(|_| self.kernels.shapes().stats_m > 0);
        let mut kernel_served = false;
        match kernel_shape {
            Some((key_field, value_field)) => {
                // Gather (key, value) columns while every record stays
                // provably lossless for the f32 kernel: an `Int` key and
                // an `F64` value that round-trips through f32. The first
                // non-conforming record bails the whole leg to the
                // scalar fold (replaying what was gathered), so the
                // kernel can never change a result — the same posture as
                // the canonical-shape gate on the find path.
                let mut pairs: Vec<(i64, f64)> = Vec::new();
                let mut eligible = true;
                while let Some((_, raw)) = self.next_scan_match(&view, &mut scan) {
                    folded += 1;
                    let rd = RawDoc::new(raw);
                    if eligible {
                        match (rd.get(key_field), rd.get(value_field)) {
                            (Some(RawValue::Int(k)), Some(RawValue::F64(v)))
                                if (v as f32) as f64 == v =>
                            {
                                pairs.push((k, v));
                                continue;
                            }
                            _ => {
                                eligible = false;
                                for &(k, v) in &pairs {
                                    table.fold_kernel_pair(pipeline, k, v);
                                }
                                pairs.clear();
                            }
                        }
                    }
                    table.fold_raw(pipeline, &rd);
                }
                if eligible {
                    table = self.kernel_accumulate(pipeline, &pairs)?;
                    kernel_served = true;
                }
            }
            None => {
                while let Some((_, raw)) = self.next_scan_match(&view, &mut scan) {
                    folded += 1;
                    table.fold_raw(pipeline, &RawDoc::new(raw));
                }
            }
        }
        self.metrics
            .counter(if kernel_served {
                names::SHARD_AGG_KERNEL_PATH
            } else {
                names::SHARD_AGG_SCALAR_PATH
            })
            .inc();
        self.metrics.counter(names::SHARD_AGG_DOCS).add(folded);
        self.flush_scan_metrics(&mut scan);
        let rows = table.into_rows();
        self.metrics.counter(names::SHARD_AGG_GROUPS).add(rows.len() as u64);
        Ok(AggregateReply { rows, docs: Vec::new(), version: fence.version })
    }

    /// Reduce gathered `(group key, value)` columns with the compiled
    /// stats kernel: groups pack as *columns* of a `[b, stats_m]` batch
    /// (the kernel reduces per column), short columns padded by
    /// repeating their first value — a no-op for min/max, the only
    /// value-dependent states a kernel-shaped pipeline has. Counts come
    /// from the scalar bucket sizes. Inputs passed the f32 round-trip
    /// gate, so the reduced min/max are bit-identical to the scalar
    /// fold's.
    fn kernel_accumulate(
        &self,
        pipeline: &AggPipeline,
        pairs: &[(i64, f64)],
    ) -> Result<PartialTable, WireError> {
        let m = self.kernels.shapes().stats_m;
        let mut order: Vec<i64> = Vec::new();
        let mut cols: HashMap<i64, Vec<f32>> = HashMap::new();
        for &(k, v) in pairs {
            cols.entry(k)
                .or_insert_with(|| {
                    order.push(k);
                    Vec::new()
                })
                .push(v as f32);
        }
        let mut table = PartialTable::new();
        for chunk in order.chunks(m) {
            let b = chunk.iter().map(|k| cols[k].len()).max().unwrap_or(0);
            if b == 0 {
                continue;
            }
            // Row-major [b, m]: column c holds group c's values; surplus
            // columns repeat column 0 and their outputs are ignored.
            let mut buf = vec![0f32; b * m];
            for (c, k) in chunk.iter().enumerate() {
                let vals = &cols[k];
                for (r, slot) in buf.chunks_exact_mut(m).enumerate() {
                    slot[c] = vals[r.min(vals.len() - 1)];
                }
            }
            for c in chunk.len()..m {
                for slot in buf.chunks_exact_mut(m) {
                    slot[c] = slot[0];
                }
            }
            let out = self
                .kernels
                .stats(&buf, b, m)
                .map_err(|e| WireError::Server(e.to_string()))?;
            for (c, k) in chunk.iter().enumerate() {
                let n = cols[k].len() as u64;
                let states = pipeline
                    .accs
                    .iter()
                    .map(|spec| match spec.op {
                        AccOp::Count => AccState::Count(n),
                        AccOp::Min => AccState::Min(Some(Value::F64(out.min[c] as f64))),
                        AccOp::Max => AccState::Max(Some(Value::F64(out.max[c] as f64))),
                        // Unreachable for kernel-shaped pipelines
                        // (`kernel_shape` excludes sum/avg); keep the
                        // fold identity so a logic slip degrades to a
                        // mergeable zero state instead of a panic.
                        AccOp::Sum => AccState::Sum(0.0),
                        AccOp::Avg => AccState::Avg { sum: 0.0, n: 0 },
                    })
                    .collect();
                table.insert_group(GroupKey::Int(*k), states);
            }
        }
        Ok(table)
    }

    /// Build the cursor source for a find: the index-ordered sort path,
    /// the kernel fast path, or a streaming scan with the raw matcher.
    fn plan_source(
        &self,
        view: &ReadView<'_>,
        filter: &Filter,
        opts: &FindOptions,
        fence: ReadFence,
    ) -> Result<CursorSource, WireError> {
        if let Some((field, dir)) = &opts.sort {
            // Index-ordered sort: a single-field index on the sort field
            // serves rids in key order (reverse scan for Desc) — the
            // limit cuts the scan off early instead of materializing,
            // decoding, and sorting every match. Worth it when the
            // index walk is bounded by the *filter* — it ranges the
            // sort field, or matches everything. A selective filter on
            // a different field (even with a limit: scarce matches
            // would walk the whole sort index before filling it) is
            // better served by its own plan + decode-once sort (below).
            let sort_index = format!("{field}_1");
            let bounded =
                filter.index_range(field).is_some() || matches!(filter, Filter::True);
            if bounded && view.index(COLLECTION, &sort_index).is_some() {
                self.metrics.counter(names::SHARD_PLAN_INDEX_SORT).inc();
                let (lo, hi) = filter.index_range(field).unwrap_or((None, None));
                let ranges = vec![Index::superset_bounds(&[], lo.as_ref(), hi.as_ref())];
                return Ok(CursorSource::Scan(ScanCursor::new(
                    ScanPlan::Index {
                        index: sort_index,
                        ranges,
                        rev: *dir == SortDir::Desc,
                    },
                    filter.clone(),
                    fence,
                )));
            }
            // Sort field not indexed: drain the unsorted plan, decoding
            // each match exactly once, sort in memory, serve from there.
            return self.sorted_fallback(view, filter, opts, field, *dir, fence);
        }
        // Kernel fast path for the canonical shape over planned
        // candidates — columns extracted raw, no document materialized.
        if let Some((lo, hi, nodes)) = canonical_shape(filter) {
            let words = self.kernels.shapes().filter_w;
            let max_node = nodes.iter().max().copied().unwrap_or(0);
            if (max_node as usize) < words * 32 && !nodes.is_empty() {
                self.metrics.counter(names::SHARD_FIND_KERNEL_PATH).inc();
                let candidates = self.drain_plan(view, self.plan_scan(view, filter));
                self.metrics
                    .counter(names::SHARD_FIND_CANDIDATES)
                    .add(candidates.len() as u64);
                let rids = self.kernel_filter(view, &candidates, lo, hi, &nodes, &fence)?;
                self.metrics.counter(names::SHARD_FIND_MATCHES).add(rids.len() as u64);
                return Ok(CursorSource::Rids { rids, pos: 0 });
            }
        }
        // General path: stream the planned scan through the raw matcher.
        self.metrics.counter(names::SHARD_FIND_MATCHER_PATH).inc();
        Ok(CursorSource::Scan(ScanCursor::new(
            self.plan_scan(view, filter),
            filter.clone(),
            fence,
        )))
    }

    /// Choose an access path for `filter` — the planner decision tree
    /// (docs/ARCHITECTURE.md §7.1). Streaming plans yield candidates
    /// lazily; the `Rids` plan is the materialized intersection/point
    /// fallback. All cardinality estimates and probes evaluate at the
    /// view's epoch, so the plan and the data it scans agree.
    fn plan_scan(&self, view: &ReadView<'_>, filter: &Filter) -> ScanPlan {
        let at = view.at();
        // 1. `$in` on node_id.
        if let Some(values) = filter.in_values("node_id") {
            let ts_range = filter.index_range("ts");
            // 1a. Compound (node_id, ts): one bounded range scan per
            // node. For the canonical shape the `$lt` upper bound is
            // known exclusive, so the bounds are *exact* — candidates
            // == matches; any other operator mix gets an inclusive
            // superset and the residual filter.
            if view.index(COLLECTION, COMPOUND_INDEX).is_some() {
                self.metrics.counter(names::SHARD_PLAN_COMPOUND).inc();
                // Exact bounds demand that the filter really pins BOTH
                // ts sides ($gte lo and $lt hi): a canonical_shape
                // default (0 / u32::MAX) encoded as an exact Int bound
                // would wrongly exclude documents whose ts is missing
                // or non-Int — keys of another type rank that a
                // ts-unconstrained filter still matches. Partial or
                // absent ts bounds take the inclusive superset and the
                // residual filter.
                let both_ts_bounds = matches!(&ts_range, Some((Some(_), Some(_))));
                let ranges: Vec<EncodedRange> = match canonical_shape(filter) {
                    Some((lo, hi, nodes)) if both_ts_bounds => nodes
                        .iter()
                        .map(|&n| {
                            let node = Value::Int(n as i64);
                            (
                                encode_key(&[&node, &Value::Int(lo as i64)]),
                                encode_key(&[&node, &Value::Int(hi as i64)]),
                            )
                        })
                        .collect(),
                    _ => {
                        let (lo, hi) = match &ts_range {
                            Some((lo, hi)) => (lo.as_ref(), hi.as_ref()),
                            None => (None, None),
                        };
                        values
                            .iter()
                            .map(|v| Index::superset_bounds(&[v], lo, hi))
                            .collect()
                    }
                };
                return ScanPlan::Index {
                    index: COMPOUND_INDEX.to_string(),
                    ranges,
                    rev: false,
                };
            }
            // 1b. Single node_id index: point lookups; with a ts index
            // and range, intersect — the probe set is built from the
            // smaller side and the larger side streams through it.
            if let Some(idx) = view.index(COLLECTION, NODE_INDEX) {
                let in_len: usize =
                    values.iter().map(|v| idx.point_len_at(&[v], at)).sum();
                if let Some((lo, hi)) = &ts_range {
                    if let Some(ts_idx) = view.index(COLLECTION, TS_INDEX) {
                        self.metrics.counter(names::SHARD_PLAN_INTERSECT).inc();
                        let ts_len =
                            ts_idx.range_superset_len_at(lo.as_ref(), hi.as_ref(), at);
                        let rids: Vec<RecordId> = if in_len <= ts_len {
                            let probe: HashSet<RecordId> = values
                                .iter()
                                .flat_map(|v| idx.point_iter_at(&[v], at))
                                .collect();
                            ts_idx
                                .range_superset_at(lo.as_ref(), hi.as_ref(), at)
                                .filter(|r| probe.contains(r))
                                .collect()
                        } else {
                            let probe: HashSet<RecordId> = ts_idx
                                .range_superset_at(lo.as_ref(), hi.as_ref(), at)
                                .collect();
                            values
                                .iter()
                                .flat_map(|v| idx.point_iter_at(&[v], at))
                                .filter(|r| probe.contains(r))
                                .collect()
                        };
                        return ScanPlan::Rids(rids);
                    }
                }
                self.metrics.counter(names::SHARD_PLAN_IN_POINTS).inc();
                let mut rids = Vec::with_capacity(in_len);
                for v in values {
                    rids.extend(idx.point_iter_at(&[v], at));
                }
                return ScanPlan::Rids(rids);
            }
        }
        // 2. Range on indexed ts (inclusive superset; the residual
        // filter restores exact operator semantics).
        if let Some((lo, hi)) = filter.index_range("ts") {
            if view.index(COLLECTION, TS_INDEX).is_some() {
                self.metrics.counter(names::SHARD_PLAN_TS_RANGE).inc();
                return ScanPlan::Index {
                    index: TS_INDEX.to_string(),
                    ranges: vec![Index::superset_bounds(&[], lo.as_ref(), hi.as_ref())],
                    rev: false,
                };
            }
        }
        // 2b. Range/eq on node_id: its own index, or the compound
        // prefix (a (node_id, ts) scan bounded on node_id alone).
        if let Some((lo, hi)) = filter.index_range("node_id") {
            for index in [NODE_INDEX, COMPOUND_INDEX] {
                if view.index(COLLECTION, index).is_some() {
                    self.metrics.counter(names::SHARD_PLAN_NODE_RANGE).inc();
                    return ScanPlan::Index {
                        index: index.to_string(),
                        ranges: vec![Index::superset_bounds(
                            &[],
                            lo.as_ref(),
                            hi.as_ref(),
                        )],
                        rev: false,
                    };
                }
            }
        }
        // 3. Full scan.
        self.metrics.counter(names::SHARD_PLAN_FULL_SCAN).inc();
        ScanPlan::Table
    }

    /// Drain a plan into a candidate rid vector (the kernel path wants
    /// whole columns).
    fn drain_plan(&self, view: &ReadView<'_>, plan: ScanPlan) -> Vec<RecordId> {
        // Candidates are not fence-filtered here: the kernel path that
        // consumes them applies the fence in `kernel_filter`.
        let mut scan = match plan {
            ScanPlan::Rids(rids) => return rids,
            plan => ScanCursor::new(plan, Filter::True, ReadFence::default()),
        };
        let mut out = Vec::new();
        loop {
            out.extend(scan.pending.drain(..));
            if !self.refill_scan(view, &mut scan) {
                break;
            }
        }
        out
    }

    /// Run the AOT filter kernel over the candidates' (ts, node_id)
    /// columns — extracted from the raw record bytes, no per-candidate
    /// document decode — and return the matching rids in order.
    fn kernel_filter(
        &self,
        view: &ReadView<'_>,
        candidates: &[RecordId],
        lo: u32,
        hi: u32,
        nodes: &[u32],
        fence: &ReadFence,
    ) -> Result<Vec<RecordId>, WireError> {
        let words = self.kernels.shapes().filter_w;
        let fence_on = fence.active();
        let mut orphans = 0u64;
        let mut ts_col = Vec::with_capacity(candidates.len());
        let mut node_col = Vec::with_capacity(candidates.len());
        let mut rids = Vec::with_capacity(candidates.len());
        for &rid in candidates {
            if let Some(raw) = view.fetch_raw(COLLECTION, rid) {
                if fence_on && fence.excludes(rid, raw) {
                    orphans += 1;
                    continue;
                }
                let d = RawDoc::new(raw);
                ts_col.push(d.get_i64("ts").unwrap_or(-1).max(0) as u32);
                node_col.push(d.get_i64("node_id").unwrap_or(0).max(0) as u32);
                rids.push(rid);
            }
        }
        if orphans > 0 {
            self.metrics.counter(names::SHARD_ORPHANS_FILTERED).add(orphans);
        }
        let bitmap = crate::runtime::fallback::build_bitmap(nodes.iter().copied(), words);
        let out = self
            .kernels
            .filter(&ts_col, &node_col, lo, hi, &bitmap)
            .map_err(|e| WireError::Server(e.to_string()))?;
        Ok(rids
            .iter()
            .zip(&out.mask)
            .filter(|(_, &m)| m == 1)
            .map(|(&rid, _)| rid)
            .collect())
    }

    /// Non-indexed sort field: drain the unsorted plan, decoding each
    /// match exactly once, sort the decoded documents, and serve the
    /// cursor from memory.
    fn sorted_fallback(
        &self,
        view: &ReadView<'_>,
        filter: &Filter,
        opts: &FindOptions,
        field: &str,
        dir: SortDir,
        fence: ReadFence,
    ) -> Result<CursorSource, WireError> {
        let mut scan = ScanCursor::new(self.plan_scan(view, filter), filter.clone(), fence);
        let mut docs: Vec<Document> = Vec::new();
        while let Some((_, raw)) = self.next_scan_match(view, &mut scan) {
            docs.push(
                RawDoc::new(raw)
                    .decode()
                    .map_err(|e| WireError::Server(format!("corrupt record: {e}")))?,
            );
        }
        self.metrics.counter(names::SHARD_FIND_DECODES).add(docs.len() as u64);
        self.flush_scan_metrics(&mut scan);
        docs.sort_by(|a, b| {
            let o = a
                .get(field)
                .unwrap_or(&Value::Null)
                .cmp_total(b.get(field).unwrap_or(&Value::Null));
            match dir {
                SortDir::Asc => o,
                SortDir::Desc => o.reverse(),
            }
        });
        // The cursor can only ever serve `limit` documents — don't keep
        // (or project) the sorted tail beyond it.
        if let Some(limit) = opts.limit {
            docs.truncate(limit);
        }
        let buf = docs
            .into_iter()
            .map(|d| match &opts.projection {
                Some(fields) => d.project(fields),
                None => d,
            })
            .collect();
        Ok(CursorSource::Docs { buf })
    }

    /// Advance a streaming scan to its next match: pull candidates from
    /// the resumable plan, raw-match each against the encoded bytes,
    /// and return the matching record id *with* its bytes (one record
    /// lookup serves both the match and the materialization).
    /// Candidate/match tallies accumulate on the cursor (flushed to the
    /// registry per served batch).
    fn next_scan_match<'v>(
        &self,
        view: &'v ReadView<'_>,
        scan: &mut ScanCursor,
    ) -> Option<(RecordId, &'v [u8])> {
        let fence_on = scan.fence.active();
        loop {
            while let Some(rid) = scan.pending.pop_front() {
                scan.seen += 1;
                let Some(raw) = view.fetch_raw(COLLECTION, rid) else {
                    continue;
                };
                if fence_on && scan.fence.excludes(rid, raw) {
                    scan.orphans += 1;
                    continue;
                }
                if scan.filter.matches_raw(&RawDoc::new(raw)) {
                    scan.matched += 1;
                    return Some((rid, raw));
                }
            }
            if scan.done || !self.refill_scan(view, scan) {
                scan.done = true;
                return None;
            }
        }
    }

    /// Pull the next key run (index plans) or record-id run (table
    /// scans) into `pending`. Returns false when the scan is exhausted.
    fn refill_scan(&self, view: &ReadView<'_>, scan: &mut ScanCursor) -> bool {
        let at = view.at();
        match &scan.plan {
            ScanPlan::Rids(rids) => {
                if scan.pos >= rids.len() {
                    return false;
                }
                let end = (scan.pos + SCAN_RUN).min(rids.len());
                scan.pending.extend(rids[scan.pos..end].iter().copied());
                scan.pos = end;
                true
            }
            ScanPlan::Index { index, ranges, rev } => {
                let Some(idx) = view.index(COLLECTION, index) else {
                    return false;
                };
                while scan.range_idx < ranges.len() {
                    let range = &ranges[scan.range_idx];
                    if let Some(key) = idx.pull_range_at(
                        range,
                        scan.after_key.as_deref(),
                        *rev,
                        SCAN_RUN,
                        &mut scan.pending,
                        at,
                    ) {
                        scan.after_key = Some(key);
                        return true;
                    }
                    scan.range_idx += 1;
                    scan.after_key = None;
                }
                false
            }
            ScanPlan::Table => {
                let before = scan.pending.len();
                for (rid, _) in view
                    .scan_raw_from(COLLECTION, scan.after_rid)
                    .take(SCAN_RUN)
                {
                    scan.after_rid = Some(rid);
                    scan.pending.push_back(rid);
                }
                scan.pending.len() > before
            }
        }
    }

    /// Publish (and reset) a scan's candidate/match tallies — batched
    /// so the per-candidate hot loop takes no registry locks.
    fn flush_scan_metrics(&self, scan: &mut ScanCursor) {
        if scan.seen > 0 {
            self.metrics.counter(names::SHARD_FIND_CANDIDATES).add(scan.seen);
            scan.seen = 0;
        }
        if scan.matched > 0 {
            self.metrics.counter(names::SHARD_FIND_MATCHES).add(scan.matched);
            scan.matched = 0;
        }
        if scan.orphans > 0 {
            self.metrics.counter(names::SHARD_ORPHANS_FILTERED).add(scan.orphans);
            scan.orphans = 0;
        }
    }

    fn serve_batch(
        &self,
        view: &ReadView<'_>,
        cur: &mut CursorState,
    ) -> Result<FindReply, WireError> {
        let mut docs = Vec::with_capacity(cur.batch.min(64));
        let mut decoded = 0u64;
        while docs.len() < cur.batch && cur.remaining != Some(0) {
            let doc = match &mut cur.src {
                CursorSource::Rids { rids, pos } => {
                    let mut out = None;
                    while out.is_none() && *pos < rids.len() {
                        let rid = rids[*pos];
                        *pos += 1;
                        if let Some(raw) = view.fetch_raw(COLLECTION, rid) {
                            decoded += 1;
                            out = Some(materialize(raw, cur.projection.as_deref())?);
                        }
                    }
                    out
                }
                // Sorted-fallback documents were decoded (and projected)
                // when the cursor was built.
                CursorSource::Docs { buf } => buf.pop_front(),
                CursorSource::Scan(scan) => match self.next_scan_match(view, scan) {
                    Some((_, raw)) => {
                        decoded += 1;
                        Some(materialize(raw, cur.projection.as_deref())?)
                    }
                    None => None,
                },
            };
            let Some(doc) = doc else { break };
            docs.push(doc);
            if let Some(r) = cur.remaining.as_mut() {
                *r -= 1;
            }
        }
        if decoded > 0 {
            self.metrics.counter(names::SHARD_FIND_DECODES).add(decoded);
        }
        if let CursorSource::Scan(scan) = &mut cur.src {
            self.flush_scan_metrics(scan);
        }
        let more = !cursor_exhausted(cur) && cur.remaining != Some(0);
        Ok(FindReply { docs, cursor: more.then_some(0) })
    }
}

struct PoolState {
    queue: VecDeque<ReadRequest>,
    closed: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    cv: Condvar,
}

/// N reader threads draining a shared queue of [`ReadRequest`]s. The
/// shard event loop submits and returns to write traffic immediately;
/// workers answer clients through the forwarded reply senders.
///
/// Shutdown drains: requests already queued are served before the
/// workers exit, so no client hangs on a dropped reply sender.
pub struct ReaderPool {
    shared: Arc<PoolShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ReaderPool {
    /// Start `threads` workers (named `<label>-rN`) over the shared
    /// read context.
    pub fn start(ctx: Arc<ReadContext>, threads: usize, label: &str) -> Self {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { queue: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        });
        let mut workers = Vec::with_capacity(threads);
        for n in 0..threads.max(1) {
            let shared = Arc::clone(&shared);
            let ctx = Arc::clone(&ctx);
            let handle = std::thread::Builder::new()
                .name(format!("{label}-r{n}"))
                .spawn(move || worker_loop(&shared, &ctx))
                // lint: allow(panic, thread spawn fails only on OS resource
                // exhaustion at shard startup, before any request is queued)
                .expect("spawn reader thread");
            workers.push(handle);
        }
        Self { shared, workers }
    }

    /// Enqueue one read request; a sleeping worker wakes to take it.
    pub fn submit(&self, req: ReadRequest) {
        let mut state = locked(&self.shared.state);
        state.queue.push_back(req);
        drop(state);
        self.shared.cv.notify_one();
    }

    /// Close the queue, serve what is already in it, and join the
    /// workers.
    pub fn shutdown(self) {
        {
            let mut state = locked(&self.shared.state);
            state.closed = true;
        }
        self.shared.cv.notify_all();
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &PoolShared, ctx: &ReadContext) {
    loop {
        let req = {
            let mut state = locked(&shared.state);
            loop {
                if let Some(r) = state.queue.pop_front() {
                    break Some(r);
                }
                if state.closed {
                    break None;
                }
                state = match shared.cv.wait(state) {
                    Ok(g) => g,
                    Err(poisoned) => poisoned.into_inner(),
                };
            }
            // Queue guard drops here — request execution (store read
            // locks, reply sends) never holds the pool lock.
        };
        match req {
            Some(req) => ctx.serve(req),
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mongo::storage::{Engine, EngineOptions, LocalDir};
    use std::sync::mpsc;

    fn doc(ts: i64, node: i64) -> Document {
        Document::new().set("ts", ts).set("node_id", node)
    }

    fn ctx_with_docs(tag: &str, n: i64) -> (Engine, Arc<ReadContext>) {
        let dir = LocalDir::temp(tag).unwrap();
        let mut eng = Engine::open_with(Box::new(dir), EngineOptions::default()).unwrap();
        eng.create_collection(COLLECTION);
        let docs: Vec<Document> = (0..n).map(|i| doc(i, i % 4)).collect();
        eng.insert_many(COLLECTION, &docs).unwrap();
        let ctx = Arc::new(ReadContext::new(
            eng.reader(),
            Kernels::fallback(),
            Registry::new(),
            1_000,
        ));
        (eng, ctx)
    }

    #[test]
    fn inline_find_serves_all_docs() {
        let (_eng, ctx) = ctx_with_docs("readctx1", 10);
        let r = ctx
            .handle_find(&Filter::True, &FindOptions::default())
            .unwrap();
        assert_eq!(r.docs.len(), 10);
        assert!(r.cursor.is_none());
    }

    #[test]
    fn cursor_pins_snapshot_across_writer_removes() {
        let (mut eng, ctx) = ctx_with_docs("readctx2", 10);
        let opts = FindOptions { batch_size: Some(3), ..FindOptions::default() };
        let first = ctx.handle_find(&Filter::True, &opts).unwrap();
        assert_eq!(first.docs.len(), 3);
        let cur = first.cursor.expect("more batches");
        // The writer removes everything and reclaims; the cursor's
        // snapshot must still drain the original ten documents.
        let rids = eng.record_ids(COLLECTION);
        eng.remove_many(COLLECTION, &rids).unwrap();
        eng.reclaim();
        assert_eq!(eng.stats(COLLECTION).docs, 0);
        let mut total = first.docs.len();
        let mut cursor = cur;
        loop {
            let r = ctx.handle_get_more(cursor).unwrap();
            total += r.docs.len();
            match r.cursor {
                Some(c) => cursor = c,
                None => break,
            }
        }
        assert_eq!(total, 10, "pinned snapshot drains the frozen result set");
        assert_eq!(ctx.open_cursors(), 0);
        // With the cursor gone, reclamation can finally drop the dead
        // versions.
        eng.reclaim();
        assert_eq!(eng.garbage_len(), 0);
    }

    #[test]
    fn expired_snapshot_surfaces_retryable_error() {
        let dir = LocalDir::temp("readctx3").unwrap();
        let opts = EngineOptions { snapshot_retention: 2, ..EngineOptions::default() };
        let mut eng = Engine::open_with(Box::new(dir), opts).unwrap();
        eng.create_collection(COLLECTION);
        let docs: Vec<Document> = (0..8).map(|i| doc(i, 0)).collect();
        eng.insert_many(COLLECTION, &docs).unwrap();
        let ctx = ReadContext::new(
            eng.reader(),
            Kernels::fallback(),
            Registry::new(),
            1_000,
        );
        let fopts = FindOptions { batch_size: Some(2), ..FindOptions::default() };
        let first = ctx.handle_find(&Filter::True, &fopts).unwrap();
        let cursor = first.cursor.expect("more batches");
        // Advance the committed epoch past the retention window, then
        // reclaim: the idle cursor's pin expires.
        for i in 0..4 {
            eng.insert_many(COLLECTION, &[doc(100 + i, 0)]).unwrap();
        }
        eng.reclaim();
        let err = ctx.handle_get_more(cursor).unwrap_err();
        assert!(
            matches!(err, WireError::SnapshotExpired { .. }),
            "expected SnapshotExpired, got {err:?}"
        );
        // The dead cursor unpinned its snapshot and left the registry.
        assert_eq!(ctx.open_cursors(), 0);
        assert_eq!(eng.snapshots_open(), 0);
    }

    #[test]
    fn pool_serves_concurrent_reads_and_drains_on_shutdown() {
        let (_eng, ctx) = ctx_with_docs("readctx4", 64);
        let pool = ReaderPool::start(Arc::clone(&ctx), 3, "t");
        let mut rxs = Vec::new();
        for i in 0..32 {
            let (tx, rx) = mpsc::channel();
            if i % 2 == 0 {
                pool.submit(ReadRequest::Find {
                    filter: Filter::True,
                    opts: FindOptions::default(),
                    reply: tx,
                });
                rxs.push((rx, None));
            } else {
                let (ctx_tx, ctx_rx) = mpsc::channel();
                pool.submit(ReadRequest::Count { filter: Filter::True, reply: ctx_tx });
                drop(tx);
                rxs.push((rx, Some(ctx_rx)));
            }
        }
        pool.shutdown();
        for (find_rx, count_rx) in rxs {
            match count_rx {
                Some(rx) => assert_eq!(rx.recv().unwrap().unwrap().n, 64),
                None => assert_eq!(find_rx.recv().unwrap().unwrap().docs.len(), 64),
            }
        }
    }

    #[test]
    fn fence_clamps_negative_keys_like_every_other_position_site() {
        // Out-of-domain (negative) key fields clamp to 0 through
        // `ShardKey::position_i64` — the same convention the router's
        // `drop_orphans` and the kernel column path use. A wrapping
        // cast here would position-classify the document differently
        // on the shard fence vs the router fence, making orphan
        // filtering inconsistent.
        let key = ShardKey::ranged();
        let raw = |node: i64, ts: i64| {
            Document::new().set("node_id", node).set("ts", ts).encode()
        };
        let low_fence = ReadFence {
            version: 1,
            key: Some(key),
            exclude_range: Some((key.position(0, 0), key.position(0, u32::MAX))),
            mask_rids: None,
        };
        // node -3 clamps to 0: inside the node-0 range, excluded.
        assert!(low_fence.excludes(0, &raw(-3, 7)));
        // negative ts clamps to 0, still node 0: excluded.
        assert!(low_fence.excludes(1, &raw(0, -5)));
        // genuinely out of range: kept.
        assert!(!low_fence.excludes(2, &raw(1, 7)));
        // A wrapping cast would have sent node -1 to u32::MAX; the
        // clamp must keep it out of the top-of-space range.
        let high_fence = ReadFence {
            version: 1,
            key: Some(key),
            exclude_range: Some((
                key.position(u32::MAX, 0),
                key.position(u32::MAX, u32::MAX),
            )),
            mask_rids: None,
        };
        assert!(!high_fence.excludes(3, &raw(-1, 5)));
        assert!(high_fence.excludes(4, &raw(u32::MAX as i64, 5)));
    }

    #[test]
    fn get_more_on_unknown_cursor_errors() {
        let (_eng, ctx) = ctx_with_docs("readctx5", 4);
        let err = ctx.handle_get_more(99).unwrap_err();
        assert!(matches!(err, WireError::UnknownCursor(99)));
    }

    /// Engine + context whose registry handle the test keeps, with a
    /// canonical-numeric corpus: Int ts/node_id plus an f64 metric
    /// column exact in f32 (`i * 0.5`).
    fn agg_fixture(tag: &str, n: i64) -> (Engine, ReadContext, Registry, Vec<Document>) {
        let dir = LocalDir::temp(tag).unwrap();
        let mut eng = Engine::open_with(Box::new(dir), EngineOptions::default()).unwrap();
        eng.create_collection(COLLECTION);
        let docs: Vec<Document> = (0..n)
            .map(|i| doc(i, i % 4).set("load", (i % 7) as f64 * 0.5))
            .collect();
        eng.insert_many(COLLECTION, &docs).unwrap();
        let metrics = Registry::new();
        let ctx =
            ReadContext::new(eng.reader(), Kernels::fallback(), metrics.clone(), 1_000);
        (eng, ctx, metrics, docs)
    }

    fn merged_result(p: &AggPipeline, rows: Vec<crate::mongo::aggregate::AggRow>) -> Vec<Document> {
        let mut t = PartialTable::new();
        t.merge_rows(p, rows);
        p.finalize(t)
    }

    #[test]
    fn aggregate_partial_agrees_with_reference_and_decodes_nothing() {
        let (_eng, ctx, metrics, docs) = agg_fixture("readagg1", 40);
        // sum/avg force the scalar fold (kernel shape excludes them).
        let p = AggPipeline::new()
            .matching(Filter::range("ts", 5i64, 35i64))
            .group_by("node_id")
            .count("n")
            .sum("total", "load")
            .avg("mean", "load");
        let r = ctx.handle_aggregate(&p, true).unwrap();
        assert!(r.docs.is_empty(), "partial mode ships no documents");
        assert!(r.rows.len() <= 4, "one row per group, not per match");
        assert_eq!(merged_result(&p, r.rows), p.execute_docs(&docs));
        // The accumulate path probes raw bytes; nothing is decoded.
        assert_eq!(metrics.counter(names::SHARD_FIND_DECODES).get(), 0);
        assert_eq!(metrics.counter(names::SHARD_AGG_SCALAR_PATH).get(), 1);
        assert_eq!(metrics.counter(names::SHARD_AGG_KERNEL_PATH).get(), 0);
        assert_eq!(metrics.counter(names::SHARD_AGG_DOCS).get(), 30);
        assert_eq!(
            metrics.counter(names::SHARD_AGG_GROUPS).get(),
            r.rows.len() as u64
        );
    }

    #[test]
    fn aggregate_kernel_path_is_lossless_and_counted() {
        let (_eng, ctx, metrics, docs) = agg_fixture("readagg2", 64);
        let p = AggPipeline::new()
            .group_by("node_id")
            .count("n")
            .min("lo", "load")
            .max("hi", "load");
        assert!(p.kernel_shape().is_some());
        let r = ctx.handle_aggregate(&p, true).unwrap();
        assert_eq!(metrics.counter(names::SHARD_AGG_KERNEL_PATH).get(), 1);
        assert_eq!(metrics.counter(names::SHARD_AGG_SCALAR_PATH).get(), 0);
        assert_eq!(metrics.counter(names::SHARD_FIND_DECODES).get(), 0);
        // f32-exact inputs: the kernel reduction is bit-identical to the
        // scalar oracle.
        assert_eq!(merged_result(&p, r.rows), p.execute_docs(&docs));
    }

    #[test]
    fn aggregate_kernel_bails_to_scalar_on_inexact_values() {
        let (mut eng, ctx, metrics, mut docs) = agg_fixture("readagg3", 16);
        // 0.1 does not round-trip through f32: the gate must bail the
        // whole leg to the scalar fold mid-scan, with identical results.
        let odd = doc(100, 1).set("load", 0.1f64);
        eng.insert_many(COLLECTION, &[odd.clone()]).unwrap();
        docs.push(odd);
        let p = AggPipeline::new()
            .group_by("node_id")
            .count("n")
            .min("lo", "load")
            .max("hi", "load");
        let r = ctx.handle_aggregate(&p, true).unwrap();
        assert_eq!(metrics.counter(names::SHARD_AGG_KERNEL_PATH).get(), 0);
        assert_eq!(metrics.counter(names::SHARD_AGG_SCALAR_PATH).get(), 1);
        assert_eq!(merged_result(&p, r.rows), p.execute_docs(&docs));
    }

    #[test]
    fn aggregate_full_ship_decodes_and_ships_every_match() {
        let (_eng, ctx, metrics, docs) = agg_fixture("readagg4", 24);
        let p = AggPipeline::new()
            .matching(Filter::range("ts", 0i64, 12i64))
            .group_by("node_id")
            .count("n")
            .avg("mean", "load");
        let r = ctx.handle_aggregate(&p, false).unwrap();
        assert!(r.rows.is_empty(), "full-ship mode ships documents");
        assert_eq!(r.docs.len(), 12, "every match crosses the wire");
        assert_eq!(metrics.counter(names::SHARD_FIND_DECODES).get(), 12);
        assert_eq!(metrics.counter(names::SHARD_AGG_DOCS).get(), 12);
        // The central fold over shipped documents is the reference
        // executor by construction.
        assert_eq!(p.execute_docs(&r.docs), p.execute_docs(&docs));
    }
}
