//! Lightweight metrics: counters, gauges, log-linear histograms, and
//! named registries, with markdown/CSV report emitters.
//!
//! Every server role (router, shard, config, scheduler, lustre OST) owns
//! a [`Registry`]; the coordinator merges them into run reports that the
//! bench harnesses print in the paper's row format.

mod histogram;
mod registry;

pub use histogram::Histogram;
pub use registry::{names, Counter, Gauge, Registry};
