//! Log-linear histogram (HdrHistogram-style) for latency recording.
//!
//! Values are bucketed into powers of two subdivided linearly 16 ways,
//! giving ≤ ~6.25% relative error over the full u64 range with a small
//! fixed footprint — good enough for p50/p95/p99 reporting.

const SUB_BUCKETS: usize = 16;
const SUB_BITS: u32 = 4; // log2(SUB_BUCKETS)
// Slots 0..16 hold values < 16 exactly; each exponent range 4..=63 then
// contributes 16 log-linear slots.
const NUM_SLOTS: usize = SUB_BUCKETS + (64 - SUB_BITS as usize) * SUB_BUCKETS;

/// Fixed-size log-linear histogram over `u64` values.
#[derive(Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // Summary only — dumping ~1k slots is useless noise.
        write!(
            f,
            "Histogram {{ n: {}, mean: {:.1}, p50: {}, p99: {}, max: {} }}",
            self.total,
            self.mean(),
            self.p50(),
            self.p99(),
            self.max
        )
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            counts: vec![0; NUM_SLOTS],
            total: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    #[inline]
    fn slot_for(value: u64) -> usize {
        // Values below SUB_BUCKETS map 1:1 into the first slot block.
        if value < SUB_BUCKETS as u64 {
            return value as usize;
        }
        let exp = 63 - value.leading_zeros(); // >= SUB_BITS
        let shift = exp - SUB_BITS; // value >> shift ∈ [16, 32)
        let sub = ((value >> shift) & (SUB_BUCKETS as u64 - 1)) as usize;
        SUB_BUCKETS + (shift as usize) * SUB_BUCKETS + sub
    }

    /// Low edge of a slot (the reported quantile value).
    fn slot_value(slot: usize) -> u64 {
        if slot < SUB_BUCKETS {
            return slot as u64;
        }
        let shift = (slot / SUB_BUCKETS - 1) as u32;
        let sub = (slot % SUB_BUCKETS) as u64;
        (SUB_BUCKETS as u64 + sub) << shift
    }

    pub fn record(&mut self, value: u64) {
        let slot = Self::slot_for(value);
        self.counts[slot] += 1;
        self.total += 1;
        self.sum += value as u128;
        self.min = self.min.min(value);
        self.max = self.max.max(value);
    }

    pub fn record_n(&mut self, value: u64, n: u64) {
        if n == 0 {
            return;
        }
        let slot = Self::slot_for(value);
        self.counts[slot] += n;
        self.total += n;
        self.sum += value as u128 * n as u128;
        self.min = self.min.min(value);
        self.max = self.max.max(value);
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Approximate quantile (`q` in [0,1]); exact min/max at the ends.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        if q <= 0.0 {
            return self.min();
        }
        if q >= 1.0 {
            return self.max;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (slot, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::slot_value(slot).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// One-line summary used in reports (values interpreted as ns).
    pub fn summary_ns(&self) -> String {
        use crate::util::fmt::human_duration_ns as d;
        format!(
            "n={} mean={} p50={} p95={} p99={} max={}",
            self.total,
            d(self.mean() as u64),
            d(self.p50()),
            d(self.p95()),
            d(self.p99()),
            d(self.max())
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_exact() {
        let mut h = Histogram::new();
        for v in 0..16u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 16);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 15);
        assert_eq!(h.quantile(0.0), 0);
        assert_eq!(h.quantile(1.0), 15);
    }

    #[test]
    fn quantiles_within_relative_error() {
        let mut h = Histogram::new();
        // Uniform 1..=100_000.
        for v in 1..=100_000u64 {
            h.record(v);
        }
        for (q, want) in [(0.5, 50_000.0), (0.95, 95_000.0), (0.99, 99_000.0)] {
            let got = h.quantile(q) as f64;
            let rel = (got - want).abs() / want;
            assert!(rel < 0.10, "q={q} got={got} want={want} rel={rel}");
        }
    }

    #[test]
    fn mean_is_exact() {
        let mut h = Histogram::new();
        for v in [10u64, 20, 30, 40] {
            h.record(v);
        }
        assert!((h.mean() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn merge_combines() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(100);
        b.record(1_000_000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min(), 100);
        assert_eq!(a.max(), 1_000_000);
    }

    #[test]
    fn record_n_weighting() {
        let mut h = Histogram::new();
        h.record_n(50, 99);
        h.record_n(5_000, 1);
        assert_eq!(h.count(), 100);
        // p50 must sit at the heavy value.
        let p50 = h.p50();
        assert!(p50 <= 64, "p50={p50}");
    }

    #[test]
    fn huge_values_do_not_panic() {
        let mut h = Histogram::new();
        h.record(u64::MAX);
        h.record(u64::MAX / 2);
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.5) > 0);
    }

    #[test]
    fn empty_histogram_is_zeroes() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.p50(), 0);
        assert_eq!(h.mean(), 0.0);
    }
}
