//! A4 — insertMany batch-size sweep on a live cluster: the trade
//! between per-call overhead (router hop + kernel invocation) and
//! batch memory/latency. The paper's clients use large `insertMany`
//! lists; this shows why.

use hpcstore::benchkit::Report;
use hpcstore::config::WorkloadConfig;
use hpcstore::metrics::Registry;
use hpcstore::mongo::cluster::{Cluster, ClusterSpec};
use hpcstore::mongo::storage::index::IndexSpec;
use hpcstore::mongo::storage::LocalDir;
use hpcstore::runtime::Kernels;
use hpcstore::workload::ovis::OvisGenerator;
use hpcstore::workload::IngestDriver;

fn main() {
    let kernels = Kernels::load_or_fallback("artifacts");
    let mut report = Report::new("A4 — insertMany batch size (live cluster, 2 shards/2 routers/4 PEs)");
    report.set_custom(
        ["batch", "docs", "docs/s", "batch p50", "batch p95", "rerouted"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    for &batch in &[50usize, 200, 1000, 4000] {
        let cluster = Cluster::start(
            ClusterSpec::small(2, 2),
            move |sid| Ok(Box::new(LocalDir::temp(&format!("a4-{batch}-{sid}"))?)),
            kernels.clone(),
            Registry::new(),
        )
        .unwrap();
        let client = cluster.client();
        client.create_index(IndexSpec::single("ts")).unwrap();
        client.create_index(IndexSpec::single("node_id")).unwrap();
        let gen = OvisGenerator::new(WorkloadConfig {
            monitored_nodes: 128,
            metrics_per_doc: 75,
            days: 8.0 / 1440.0,
            ..Default::default()
        });
        let rep = IngestDriver::new(gen, batch, 4).run(&client).unwrap();
        report.add_row(vec![
            batch.to_string(),
            rep.docs.to_string(),
            format!("{:.0}", rep.docs_per_sec),
            hpcstore::util::fmt::human_duration_ns(rep.batch_latency.p50()),
            hpcstore::util::fmt::human_duration_ns(rep.batch_latency.p95()),
            rep.rerouted.to_string(),
        ]);
        cluster.shutdown();
    }
    report.print();
}
