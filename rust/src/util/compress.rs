//! LZSS compression for checkpoint blocks (`flate2` is not in the
//! vendored set; this in-tree codec fills the role).
//!
//! Stream format: groups of one control byte followed by up to eight
//! items. Control bit `i` (LSB first) describes item `i`:
//! `0` = literal (one raw byte), `1` = back-reference (two bytes:
//! `b0 = (offset-1) & 0xFF`, `b1 = ((offset-1) >> 8) << 4 | (len-3)`),
//! with offsets in `[1, 4096]` and lengths in `[3, 18]`. Matches may
//! overlap their own output (run-length encoding falls out naturally).
//!
//! Checkpoints are dominated by repeated field names and near-identical
//! record layouts, which this window/length combination captures well;
//! the codec is deterministic and allocation-light in the hot loop.

use std::collections::{HashMap, VecDeque};

use anyhow::{bail, Result};

const WINDOW: usize = 4096;
const MIN_MATCH: usize = 3;
const MAX_MATCH: usize = 18;
/// Cap per-hash candidate chains so pathological inputs stay linear.
const MAX_CHAIN: usize = 32;

#[inline]
fn hash3(data: &[u8], i: usize) -> u32 {
    (data[i] as u32) | ((data[i + 1] as u32) << 8) | ((data[i + 2] as u32) << 16)
}

/// Record position `i` in its hash chain (if a 3-byte prefix fits).
#[inline]
fn chain_insert(table: &mut HashMap<u32, VecDeque<usize>>, data: &[u8], i: usize) {
    if i + MIN_MATCH <= data.len() {
        let chain = table.entry(hash3(data, i)).or_default();
        chain.push_back(i);
        if chain.len() > MAX_CHAIN {
            chain.pop_front();
        }
    }
}

/// Compress `data`. Always succeeds; worst case grows by 1/8 + 1 bytes.
pub fn compress(data: &[u8]) -> Vec<u8> {
    let n = data.len();
    let mut out = Vec::with_capacity(n / 2 + 16);
    let mut table: HashMap<u32, VecDeque<usize>> = HashMap::new();

    let mut ctrl_pos = out.len();
    out.push(0u8);
    let mut ctrl = 0u8;
    let mut nitems = 0u8;

    let mut i = 0usize;
    while i < n {
        let mut best_len = 0usize;
        let mut best_off = 0usize;
        if i + MIN_MATCH <= n {
            if let Some(cands) = table.get_mut(&hash3(data, i)) {
                while let Some(&front) = cands.front() {
                    if front + WINDOW < i {
                        cands.pop_front();
                    } else {
                        break;
                    }
                }
                let limit = MAX_MATCH.min(n - i);
                for &j in cands.iter().rev() {
                    let mut l = 0usize;
                    while l < limit && data[j + l] == data[i + l] {
                        l += 1;
                    }
                    if l > best_len {
                        best_len = l;
                        best_off = i - j;
                        if l == limit {
                            break;
                        }
                    }
                }
            }
        }
        if best_len >= MIN_MATCH {
            ctrl |= 1 << nitems;
            let om1 = best_off - 1;
            out.push((om1 & 0xFF) as u8);
            out.push((((om1 >> 8) << 4) | (best_len - MIN_MATCH)) as u8);
            let end = i + best_len;
            while i < end {
                chain_insert(&mut table, data, i);
                i += 1;
            }
        } else {
            out.push(data[i]);
            chain_insert(&mut table, data, i);
            i += 1;
        }
        nitems += 1;
        if nitems == 8 {
            out[ctrl_pos] = ctrl;
            ctrl_pos = out.len();
            out.push(0);
            ctrl = 0;
            nitems = 0;
        }
    }
    out[ctrl_pos] = ctrl;
    if nitems == 0 {
        out.pop();
    }
    out
}

/// Decompress a [`compress`] stream. Errors on truncated or corrupt
/// input (a back-reference pointing before the start of the output).
pub fn decompress(comp: &[u8]) -> Result<Vec<u8>> {
    let n = comp.len();
    let mut out = Vec::with_capacity(n * 2);
    let mut i = 0usize;
    while i < n {
        let ctrl = comp[i];
        i += 1;
        for bit in 0..8u8 {
            if i >= n {
                break;
            }
            if ctrl & (1 << bit) != 0 {
                if i + 2 > n {
                    bail!("truncated back-reference at byte {i}");
                }
                let b0 = comp[i] as usize;
                let b1 = comp[i + 1] as usize;
                i += 2;
                let off = ((b1 >> 4) << 8 | b0) + 1;
                let len = (b1 & 0x0F) + MIN_MATCH;
                if off > out.len() {
                    bail!("back-reference offset {off} before stream start");
                }
                let start = out.len() - off;
                for k in 0..len {
                    let b = out[start + k];
                    out.push(b);
                }
            } else {
                out.push(comp[i]);
                i += 1;
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn roundtrip(data: &[u8]) {
        let c = compress(data);
        let back = decompress(&c).unwrap();
        assert_eq!(back, data, "roundtrip failed for {} bytes", data.len());
    }

    #[test]
    fn edge_cases() {
        roundtrip(b"");
        roundtrip(b"a");
        roundtrip(b"ab");
        roundtrip(b"abc");
        roundtrip(&[0u8; 100_000]);
        roundtrip(b"abcabcabcabcabcabcabcabc");
        let all: Vec<u8> = (0..=255u8).cycle().take(2048).collect();
        roundtrip(&all);
    }

    #[test]
    fn random_binary_roundtrips() {
        let mut rng = Pcg32::seeded(0x1255);
        for _ in 0..30 {
            let n = rng.next_bounded(5000) as usize;
            let data: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
            roundtrip(&data);
        }
    }

    #[test]
    fn repetitive_data_shrinks() {
        // Checkpoint-like: repeated record with field names.
        let rec = b"\x05\x00ts\x02node_id\x03cpu_user....................";
        let data: Vec<u8> = rec.iter().copied().cycle().take(50_000).collect();
        let c = compress(&data);
        assert!(c.len() * 3 < data.len(), "{} not < {}/3", c.len(), data.len());
        roundtrip(&data);
    }

    #[test]
    fn long_range_matches_beyond_window_still_roundtrip() {
        let mut rng = Pcg32::seeded(7);
        let base: Vec<u8> = (0..300).map(|_| rng.next_u32() as u8).collect();
        let mut data = base.clone();
        data.extend((0..6000).map(|_| rng.next_u32() as u8));
        data.extend_from_slice(&base); // repeat outside the window
        roundtrip(&data);
    }

    #[test]
    fn decompress_rejects_corrupt_input() {
        // Control byte says "match" but only one byte follows.
        assert!(decompress(&[0b0000_0001, 0x00]).is_err());
        // Back-reference before stream start.
        assert!(decompress(&[0b0000_0001, 0x05, 0x00]).is_err());
    }

    /// Property-style round-trip sweep: for every seed, generate buffers
    /// from three distributions — incompressible (uniform random bytes),
    /// highly repetitive (tiny alphabet, long runs), and checkpoint-like
    /// (structured records with shared field names) — across sizes that
    /// straddle the control-group width, the minimum match length, and
    /// the 4 KiB window. The expansion bound (1/8 + 1 extra bytes, from
    /// one control byte per 8 items) must hold even on random input.
    #[test]
    fn property_roundtrip_across_distributions_and_sizes() {
        let sizes = [
            0usize,
            1,
            2,
            MIN_MATCH - 1,
            MIN_MATCH,
            7,
            8,
            9,
            WINDOW - 1,
            WINDOW,
            WINDOW + 1,
            3 * WINDOW + 17,
        ];
        for seed in 0..8u64 {
            let mut rng = Pcg32::seeded(0xC0DE_C0DE ^ seed);
            for &n in &sizes {
                // Incompressible: uniform random bytes.
                let random: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
                let c = compress(&random);
                assert!(
                    c.len() <= random.len() + random.len() / 8 + 1,
                    "expansion bound violated: {} -> {}",
                    random.len(),
                    c.len()
                );
                assert_eq!(decompress(&c).unwrap(), random, "random n={n} seed={seed}");

                // Highly repetitive: runs over a 3-symbol alphabet.
                let repetitive: Vec<u8> = (0..n)
                    .map(|_| b"abc"[(rng.next_bounded(3)) as usize])
                    .collect();
                roundtrip(&repetitive);

                // Checkpoint-like: records with shared field names and a
                // varying numeric tail.
                let mut structured = Vec::with_capacity(n);
                while structured.len() < n {
                    structured.extend_from_slice(b"ts\x00node_id\x00m");
                    structured.push(rng.next_u32() as u8);
                }
                structured.truncate(n);
                roundtrip(&structured);
            }
        }
    }
}
