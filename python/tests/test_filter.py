"""filter_scan Pallas kernel vs pure-jnp oracle — bit-exact."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.filter_scan import filter_scan
from compile import model

RNG = np.random.default_rng(0xF11E)


def make_bitmap(nodes, words):
    bm = np.zeros(words, dtype=np.uint32)
    for n in nodes:
        bm[n >> 5] |= np.uint32(1) << np.uint32(n & 31)
    return bm


def run_both(ts, node, lo, hi, bitmap, block_b):
    args = (
        jnp.asarray(ts),
        jnp.asarray(node),
        jnp.asarray(np.array([lo], dtype=np.uint32)),
        jnp.asarray(np.array([hi], dtype=np.uint32)),
        jnp.asarray(bitmap),
    )
    mask_k, count_k = filter_scan(*args, block_b=block_b)
    mask_r, count_r = ref.filter_ref(*args)
    return np.asarray(mask_k), np.asarray(count_k), np.asarray(mask_r), np.asarray(count_r)


def numpy_oracle(ts, node, lo, hi, bitmap):
    word = bitmap[node >> 5]
    bit = (word >> (node & 31)) & 1
    return ((lo <= ts) & (ts < hi) & (bit == 1)).astype(np.int32)


def test_kernel_matches_ref_default_shapes():
    b, w = model.FILTER_B, model.FILTER_W
    ts = RNG.integers(0, 2**22, size=b, dtype=np.uint32)
    node = RNG.integers(0, w * 32, size=b, dtype=np.uint32)
    members = RNG.choice(w * 32, size=300, replace=False)
    bitmap = make_bitmap(members, w)
    lo, hi = 2**20, 2**21
    mk, ck, mr, cr = run_both(ts, node, lo, hi, bitmap, block_b=1024)
    np.testing.assert_array_equal(mk, mr)
    np.testing.assert_array_equal(ck, cr)
    np.testing.assert_array_equal(mk, numpy_oracle(ts, node, lo, hi, bitmap))
    assert ck[0] == mk.sum()


def test_half_open_range_semantics():
    """ts == hi must NOT match; ts == lo must match."""
    w = model.FILTER_W
    bitmap = make_bitmap([7], w)
    ts = np.array([100, 100, 200, 200, 150, 99], dtype=np.uint32)
    node = np.array([7, 8, 7, 7, 7, 7], dtype=np.uint32)
    mk, ck, mr, _ = run_both(ts, node, 100, 200, bitmap, block_b=6)
    want = np.array([1, 0, 0, 0, 1, 0], dtype=np.int32)
    np.testing.assert_array_equal(mk, want)
    np.testing.assert_array_equal(mr, want)
    assert ck[0] == 2


def test_empty_bitmap_matches_nothing():
    b, w = 512, model.FILTER_W
    ts = RNG.integers(0, 2**22, size=b, dtype=np.uint32)
    node = RNG.integers(0, w * 32, size=b, dtype=np.uint32)
    bitmap = np.zeros(w, dtype=np.uint32)
    mk, ck, _, _ = run_both(ts, node, 0, 2**32 - 1, bitmap, block_b=512)
    assert mk.sum() == 0 and ck[0] == 0


def test_full_bitmap_full_range_matches_everything():
    b, w = 512, model.FILTER_W
    ts = RNG.integers(0, 2**22, size=b, dtype=np.uint32)
    node = RNG.integers(0, w * 32, size=b, dtype=np.uint32)
    bitmap = np.full(w, 0xFFFFFFFF, dtype=np.uint32)
    mk, ck, _, _ = run_both(ts, node, 0, 2**32 - 1, bitmap, block_b=512)
    # ts < 2**32-1 always holds for our ts range.
    assert mk.sum() == b and ck[0] == b


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    log_b=st.integers(min_value=0, max_value=3),
    members=st.integers(min_value=0, max_value=64),
    lo=st.integers(min_value=0, max_value=2**32 - 1),
    span=st.integers(min_value=0, max_value=2**20),
)
def test_property_kernel_equals_ref(seed, log_b, members, lo, span):
    b = 64 * (2**log_b)
    w = 64  # smaller bitmap for property runs (node ids < 2048)
    rng = np.random.default_rng(seed)
    ts = rng.integers(0, 2**32, size=b, dtype=np.uint32)
    node = rng.integers(0, w * 32, size=b, dtype=np.uint32)
    member_ids = rng.choice(w * 32, size=members, replace=False) if members else []
    bitmap = make_bitmap(member_ids, w)
    hi = min(lo + span, 2**32 - 1)
    mk, ck, mr, cr = run_both(ts, node, lo, hi, bitmap, block_b=min(b, 64))
    np.testing.assert_array_equal(mk, mr)
    np.testing.assert_array_equal(ck, cr)
    np.testing.assert_array_equal(mk, numpy_oracle(ts, node, np.uint32(lo), np.uint32(hi), bitmap))
