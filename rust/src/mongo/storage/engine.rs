//! WiredTiger-like storage engine: record store + write-ahead journal +
//! checkpoints, multiplexing any number of collections over one
//! [`StorageDir`].
//!
//! Write path: encode document → append journal record (durable at the
//! next group-commit `sync`) → insert into the in-memory record store →
//! update secondary indexes. `checkpoint()` snapshots all collections
//! (optionally LZSS-compressed) and truncates the journal; `open()`
//! recovers checkpoint + journal replay, so a shard restarted by a later
//! batch job resumes from its Lustre directory — the paper's central
//! persistence story.
//!
//! Journal record: `u32 len | u8 op | u8 coll_len | coll | payload`,
//! op 1 = insert(doc bytes), op 2 = remove(rid u64 + doc bytes for index
//! maintenance), op 3 = insert_many(u32 count, then per document
//! `u32 len | doc bytes`). An insert_many batch is one frame: recovery
//! replays it atomically or — when the frame is torn by a mid-batch
//! crash — discards it in full, never half-applied.

use std::collections::{BTreeMap, HashMap};

use anyhow::{bail, Context, Result};

use super::index::{Index, IndexSpec};
use super::io::{StorageDir, StorageFile};
use crate::mongo::bson::Document;
use crate::util::compress;

/// Record identifier within a collection.
pub type RecordId = u64;

const JOURNAL: &str = "journal.wal";
const OP_INSERT: u8 = 1;
const OP_REMOVE: u8 = 2;
const OP_INSERT_MANY: u8 = 3;
const CKPT_MAGIC: &[u8; 8] = b"HPCCKPT1";

/// Per-collection statistics.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CollectionStats {
    pub docs: u64,
    pub bytes: u64,
    pub index_entries: u64,
}

struct Collection {
    records: BTreeMap<RecordId, Vec<u8>>,
    next_rid: RecordId,
    indexes: Vec<Index>,
    bytes: u64,
}

impl Collection {
    fn new() -> Self {
        Self { records: BTreeMap::new(), next_rid: 0, indexes: Vec::new(), bytes: 0 }
    }

    fn insert_decoded(&mut self, doc: &Document, encoded: Vec<u8>) -> RecordId {
        let rid = self.next_rid;
        self.next_rid += 1;
        self.bytes += encoded.len() as u64;
        self.records.insert(rid, encoded);
        for idx in &mut self.indexes {
            idx.insert(doc, rid);
        }
        rid
    }

    fn remove(&mut self, rid: RecordId) -> Result<Document> {
        // Decode before mutating: if the record bytes are corrupt, the
        // byte accounting and index state must be left untouched.
        let bytes = self
            .records
            .get(&rid)
            .ok_or_else(|| anyhow::anyhow!("no record {rid}"))?;
        let doc = Document::decode(bytes)?;
        if let Some(bytes) = self.records.remove(&rid) {
            self.bytes -= bytes.len() as u64;
        }
        for idx in &mut self.indexes {
            idx.remove(&doc, rid);
        }
        Ok(doc)
    }
}

/// The storage engine. Single-threaded by design: each shard server
/// thread owns one engine (WiredTiger-style, one cache per `mongod`).
pub struct Engine {
    dir: Box<dyn StorageDir>,
    journal: Option<Box<dyn StorageFile>>,
    collections: HashMap<String, Collection>,
    journal_enabled: bool,
    compress_checkpoints: bool,
    journal_buf: Vec<u8>,
}

impl Engine {
    /// Open (or create) an engine on `dir`, recovering any checkpoint +
    /// journal found there.
    pub fn open(
        dir: Box<dyn StorageDir>,
        journal_enabled: bool,
        compress_checkpoints: bool,
    ) -> Result<Self> {
        let mut eng = Self {
            journal: None,
            dir,
            collections: HashMap::new(),
            journal_enabled,
            compress_checkpoints,
            journal_buf: Vec::new(),
        };
        eng.recover()?;
        if journal_enabled {
            eng.journal = Some(eng.dir.append_to(JOURNAL)?);
        }
        Ok(eng)
    }

    /// Create a collection if missing.
    pub fn create_collection(&mut self, name: &str) {
        self.collections.entry(name.to_string()).or_insert_with(Collection::new);
    }

    pub fn create_index(&mut self, coll: &str, spec: IndexSpec) -> Result<()> {
        self.create_collection(coll);
        let c = self.collections.get_mut(coll).unwrap();
        if c.indexes.iter().any(|i| i.spec == spec) {
            return Ok(());
        }
        let mut idx = Index::new(spec);
        // Backfill from existing records.
        for (rid, bytes) in &c.records {
            idx.insert(&Document::decode(bytes)?, *rid);
        }
        c.indexes.push(idx);
        Ok(())
    }

    /// Insert one document. Durable after the next [`Self::sync`].
    pub fn insert(&mut self, coll: &str, doc: &Document) -> Result<RecordId> {
        // Check the collection before journaling: a failed insert must
        // not leave a record in the journal buffer that would
        // materialize on replay.
        if !self.collections.contains_key(coll) {
            bail!("no collection `{coll}`");
        }
        let encoded = doc.encode();
        if self.journal_enabled {
            Self::journal_record(&mut self.journal_buf, OP_INSERT, coll, &encoded);
        }
        let c = self.collections.get_mut(coll).expect("collection checked above");
        Ok(c.insert_decoded(doc, encoded))
    }

    /// Insert a whole batch as **one** multi-record journal frame — the
    /// group-commit unit of the bulk write path. Recovery replays the
    /// frame atomically; a frame torn by a mid-batch crash is discarded
    /// in full. Durable after the next [`Self::sync`].
    pub fn insert_many(&mut self, coll: &str, docs: &[Document]) -> Result<Vec<RecordId>> {
        if docs.is_empty() {
            return Ok(Vec::new());
        }
        anyhow::ensure!(docs.len() <= u32::MAX as usize, "insert_many batch too large");
        if !self.collections.contains_key(coll) {
            bail!("no collection `{coll}`");
        }
        let encoded: Vec<Vec<u8>> = docs.iter().map(Document::encode).collect();
        if self.journal_enabled {
            let payload_len = 4 + encoded.iter().map(|e| 4 + e.len()).sum::<usize>();
            let mut payload = Vec::with_capacity(payload_len);
            payload.extend_from_slice(&(docs.len() as u32).to_le_bytes());
            for e in &encoded {
                payload.extend_from_slice(&(e.len() as u32).to_le_bytes());
                payload.extend_from_slice(e);
            }
            Self::journal_record(&mut self.journal_buf, OP_INSERT_MANY, coll, &payload);
        }
        let c = self.collections.get_mut(coll).expect("collection checked above");
        let mut rids = Vec::with_capacity(docs.len());
        for (doc, enc) in docs.iter().zip(encoded) {
            rids.push(c.insert_decoded(doc, enc));
        }
        Ok(rids)
    }

    /// Remove a record (chunk migration source side).
    pub fn remove(&mut self, coll: &str, rid: RecordId) -> Result<Document> {
        let c = self
            .collections
            .get_mut(coll)
            .ok_or_else(|| anyhow::anyhow!("no collection `{coll}`"))?;
        let doc = c.remove(rid)?;
        if self.journal_enabled {
            let mut payload = rid.to_le_bytes().to_vec();
            payload.extend_from_slice(&doc.encode());
            Self::journal_record(&mut self.journal_buf, OP_REMOVE, coll, &payload);
        }
        Ok(doc)
    }

    /// Group commit: flush buffered journal records to the directory.
    pub fn sync(&mut self) -> Result<()> {
        if !self.journal_enabled || self.journal_buf.is_empty() {
            return Ok(());
        }
        let j = self.journal.as_mut().expect("journal open");
        j.append(&self.journal_buf)?;
        j.sync()?;
        self.journal_buf.clear();
        Ok(())
    }

    pub fn fetch(&self, coll: &str, rid: RecordId) -> Option<Document> {
        self.collections
            .get(coll)?
            .records
            .get(&rid)
            .map(|b| Document::decode(b).expect("corrupt record"))
    }

    /// Full scan in record-id order.
    pub fn scan<'a>(
        &'a self,
        coll: &str,
    ) -> Box<dyn Iterator<Item = (RecordId, Document)> + 'a> {
        match self.collections.get(coll) {
            Some(c) => Box::new(
                c.records
                    .iter()
                    .map(|(rid, b)| (*rid, Document::decode(b).expect("corrupt record"))),
            ),
            None => Box::new(std::iter::empty()),
        }
    }

    /// Record ids only (migration batching).
    pub fn record_ids(&self, coll: &str) -> Vec<RecordId> {
        self.collections
            .get(coll)
            .map(|c| c.records.keys().copied().collect())
            .unwrap_or_default()
    }

    pub fn index(&self, coll: &str, name: &str) -> Option<&Index> {
        self.collections
            .get(coll)?
            .indexes
            .iter()
            .find(|i| i.spec.name == name)
    }

    pub fn indexes(&self, coll: &str) -> Vec<&IndexSpec> {
        self.collections
            .get(coll)
            .map(|c| c.indexes.iter().map(|i| &i.spec).collect())
            .unwrap_or_default()
    }

    pub fn stats(&self, coll: &str) -> CollectionStats {
        match self.collections.get(coll) {
            Some(c) => CollectionStats {
                docs: c.records.len() as u64,
                bytes: c.bytes,
                index_entries: c.indexes.iter().map(|i| i.entries()).sum(),
            },
            None => CollectionStats::default(),
        }
    }

    pub fn collection_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.collections.keys().cloned().collect();
        names.sort();
        names
    }

    /// Snapshot all collections to a checkpoint file and truncate the
    /// journal.
    ///
    /// Checkpoint layout: magic, u8 compressed, u32 ncolls, then per
    /// collection: u8 name_len, name, u64 next_rid, u32 n_indexes,
    /// per index (u8 len, joined field names), u64 nrecords, then
    /// records (u64 rid, u32 len, bytes). Payload after the flags byte is
    /// LZSS-compressed when enabled.
    pub fn checkpoint(&mut self) -> Result<()> {
        let mut body = Vec::new();
        let mut names: Vec<&String> = self.collections.keys().collect();
        names.sort();
        body.extend_from_slice(&(names.len() as u32).to_le_bytes());
        for name in names {
            let c = &self.collections[name];
            body.push(name.len() as u8);
            body.extend_from_slice(name.as_bytes());
            body.extend_from_slice(&c.next_rid.to_le_bytes());
            body.extend_from_slice(&(c.indexes.len() as u32).to_le_bytes());
            for idx in &c.indexes {
                let joined = idx.spec.fields.join(",");
                body.push(joined.len() as u8);
                body.extend_from_slice(joined.as_bytes());
            }
            body.extend_from_slice(&(c.records.len() as u64).to_le_bytes());
            for (rid, bytes) in &c.records {
                body.extend_from_slice(&rid.to_le_bytes());
                body.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
                body.extend_from_slice(bytes);
            }
        }
        let mut out = CKPT_MAGIC.to_vec();
        if self.compress_checkpoints {
            out.push(1);
            out.extend_from_slice(&compress::compress(&body));
        } else {
            out.push(0);
            out.extend_from_slice(&body);
        }
        self.dir.write_atomic("store.ckpt", &out)?;
        // Truncate the journal: everything is in the checkpoint now.
        if self.journal_enabled {
            self.journal_buf.clear();
            self.journal = Some(self.dir.create(JOURNAL)?);
        }
        Ok(())
    }

    fn recover(&mut self) -> Result<()> {
        if self.dir.exists("store.ckpt") {
            let raw = self.dir.read("store.ckpt")?;
            self.load_checkpoint(&raw)
                .with_context(|| format!("corrupt checkpoint in {}", self.dir.describe()))?;
        }
        if self.dir.exists(JOURNAL) {
            let raw = self.dir.read(JOURNAL)?;
            self.replay_journal(&raw)
                .with_context(|| format!("corrupt journal in {}", self.dir.describe()))?;
        }
        Ok(())
    }

    fn load_checkpoint(&mut self, raw: &[u8]) -> Result<()> {
        if raw.len() < 9 || &raw[..8] != CKPT_MAGIC {
            bail!("bad checkpoint magic");
        }
        let body: Vec<u8> = if raw[8] == 1 {
            compress::decompress(&raw[9..])?
        } else {
            raw[9..].to_vec()
        };
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > body.len() {
                bail!("truncated checkpoint");
            }
            let s = &body[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let ncolls = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        for _ in 0..ncolls {
            let name_len = take(&mut pos, 1)?[0] as usize;
            let name = std::str::from_utf8(take(&mut pos, name_len)?)?.to_string();
            let next_rid = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?);
            let n_idx = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
            let mut specs = Vec::new();
            for _ in 0..n_idx {
                let len = take(&mut pos, 1)?[0] as usize;
                let joined = std::str::from_utf8(take(&mut pos, len)?)?;
                let fields: Vec<&str> = joined.split(',').collect();
                specs.push(IndexSpec::compound(&fields));
            }
            let nrec = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?);
            let mut c = Collection::new();
            for spec in specs {
                c.indexes.push(Index::new(spec));
            }
            for _ in 0..nrec {
                let rid = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?);
                let len = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
                let bytes = take(&mut pos, len)?.to_vec();
                let doc = Document::decode(&bytes)?;
                c.bytes += bytes.len() as u64;
                c.records.insert(rid, bytes);
                for idx in &mut c.indexes {
                    idx.insert(&doc, rid);
                }
            }
            c.next_rid = next_rid;
            self.collections.insert(name, c);
        }
        Ok(())
    }

    fn replay_journal(&mut self, raw: &[u8]) -> Result<()> {
        let mut pos = 0usize;
        while pos + 4 <= raw.len() {
            let len = u32::from_le_bytes(raw[pos..pos + 4].try_into()?) as usize;
            pos += 4;
            if pos + len > raw.len() {
                // Torn tail write — stop at the last complete frame. A
                // half-written insert_many frame is dropped whole here,
                // so a mid-batch crash never half-applies a batch.
                eprintln!("warn: journal tail truncated at byte {pos}; dropping partial record");
                break;
            }
            let rec = &raw[pos..pos + len];
            pos += len;
            if rec.len() < 2 {
                bail!("journal record shorter than its header");
            }
            let op = rec[0];
            let coll_len = rec[1] as usize;
            if 2 + coll_len > rec.len() {
                bail!("journal record collection name overruns frame");
            }
            let coll = std::str::from_utf8(&rec[2..2 + coll_len])?.to_string();
            let payload = &rec[2 + coll_len..];
            self.create_collection(&coll);
            let c = self.collections.get_mut(&coll).unwrap();
            match op {
                OP_INSERT => {
                    let doc = Document::decode(payload)?;
                    c.insert_decoded(&doc, payload.to_vec());
                }
                OP_REMOVE => {
                    if payload.len() < 8 {
                        bail!("remove record shorter than its rid");
                    }
                    let rid = u64::from_le_bytes(payload[..8].try_into()?);
                    let _ = c.remove(rid);
                }
                OP_INSERT_MANY => {
                    if payload.len() < 4 {
                        bail!("insert_many frame missing count");
                    }
                    let ndocs = u32::from_le_bytes(payload[..4].try_into()?) as usize;
                    let mut p = 4usize;
                    for i in 0..ndocs {
                        if p + 4 > payload.len() {
                            bail!("insert_many frame truncated at doc {i} length");
                        }
                        let dl = u32::from_le_bytes(payload[p..p + 4].try_into()?) as usize;
                        p += 4;
                        if p + dl > payload.len() {
                            bail!("insert_many frame truncated at doc {i} body");
                        }
                        let bytes = payload[p..p + dl].to_vec();
                        p += dl;
                        let doc = Document::decode(&bytes)?;
                        c.insert_decoded(&doc, bytes);
                    }
                    if p != payload.len() {
                        bail!("insert_many frame has trailing bytes");
                    }
                }
                _ => bail!("unknown journal op {op}"),
            }
        }
        Ok(())
    }

    fn journal_record(buf: &mut Vec<u8>, op: u8, coll: &str, payload: &[u8]) {
        let len = 2 + coll.len() + payload.len();
        buf.extend_from_slice(&(len as u32).to_le_bytes());
        buf.push(op);
        buf.push(coll.len() as u8);
        buf.extend_from_slice(coll.as_bytes());
        buf.extend_from_slice(payload);
    }

    /// Bytes of journal waiting for the next group commit (tests/metrics).
    pub fn pending_journal_bytes(&self) -> usize {
        self.journal_buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mongo::bson::Value;
    use crate::mongo::storage::io::LocalDir;

    fn doc(ts: i64, node: i64) -> Document {
        Document::new().set("ts", ts).set("node_id", node).set("m0", ts as f64 * 0.5)
    }

    fn temp_engine(label: &str, journal: bool, compress: bool) -> (Engine, String) {
        let dir = LocalDir::temp(label).unwrap();
        let path = dir.describe();
        let eng = Engine::open(Box::new(dir), journal, compress).unwrap();
        (eng, path)
    }

    #[test]
    fn insert_fetch_scan() {
        let (mut eng, _) = temp_engine("eng1", true, false);
        eng.create_collection("metrics");
        let r0 = eng.insert("metrics", &doc(1, 10)).unwrap();
        let r1 = eng.insert("metrics", &doc(2, 20)).unwrap();
        assert_ne!(r0, r1);
        assert_eq!(eng.fetch("metrics", r0).unwrap().get_i64("node_id"), Some(10));
        assert_eq!(eng.scan("metrics").count(), 2);
        let s = eng.stats("metrics");
        assert_eq!(s.docs, 2);
        assert!(s.bytes > 0);
    }

    #[test]
    fn indexes_maintained_on_insert_and_remove() {
        let (mut eng, _) = temp_engine("eng2", false, false);
        eng.create_collection("metrics");
        eng.create_index("metrics", IndexSpec::single("node_id")).unwrap();
        let r0 = eng.insert("metrics", &doc(1, 7)).unwrap();
        eng.insert("metrics", &doc(2, 7)).unwrap();
        let idx = eng.index("metrics", "node_id_1").unwrap();
        assert_eq!(idx.point(&[&Value::Int(7)]).len(), 2);
        eng.remove("metrics", r0).unwrap();
        let idx = eng.index("metrics", "node_id_1").unwrap();
        assert_eq!(idx.point(&[&Value::Int(7)]).len(), 1);
    }

    #[test]
    fn index_backfills_existing_records() {
        let (mut eng, _) = temp_engine("eng3", false, false);
        eng.create_collection("metrics");
        for t in 0..20 {
            eng.insert("metrics", &doc(t, t % 4)).unwrap();
        }
        eng.create_index("metrics", IndexSpec::single("ts")).unwrap();
        let idx = eng.index("metrics", "ts_1").unwrap();
        assert_eq!(idx.range(Some(&Value::Int(5)), Some(&Value::Int(15))).len(), 10);
    }

    #[test]
    fn journal_recovery_after_crash() {
        let dir = LocalDir::temp("eng4").unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("metrics");
            for t in 0..10 {
                eng.insert("metrics", &doc(t, 1)).unwrap();
            }
            eng.sync().unwrap();
            // Drop without checkpoint = crash.
        }
        let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("metrics").docs, 10);
        assert_eq!(eng.fetch("metrics", 3).unwrap().get_i64("ts"), Some(3));
    }

    #[test]
    fn unsynced_writes_are_lost_on_crash() {
        let dir = LocalDir::temp("eng5").unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("metrics");
            eng.insert("metrics", &doc(1, 1)).unwrap();
            eng.sync().unwrap();
            eng.insert("metrics", &doc(2, 2)).unwrap();
            // no sync — buffered record lost
            assert!(eng.pending_journal_bytes() > 0);
        }
        let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("metrics").docs, 1);
    }

    #[test]
    fn checkpoint_then_recover_without_journal_replay() {
        for compress in [false, true] {
            let dir = LocalDir::temp("eng6").unwrap();
            let root = dir.describe();
            {
                let mut eng = Engine::open(Box::new(dir), true, compress).unwrap();
                eng.create_collection("metrics");
                eng.create_index("metrics", IndexSpec::single("node_id")).unwrap();
                for t in 0..25 {
                    eng.insert("metrics", &doc(t, t % 3)).unwrap();
                }
                eng.sync().unwrap();
                eng.checkpoint().unwrap();
                // Post-checkpoint writes land in the fresh journal.
                eng.insert("metrics", &doc(100, 9)).unwrap();
                eng.sync().unwrap();
            }
            let eng =
                Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, compress).unwrap();
            assert_eq!(eng.stats("metrics").docs, 26, "compress={compress}");
            // Indexes rebuilt from checkpoint specs + journal replay.
            let idx = eng.index("metrics", "node_id_1").unwrap();
            assert_eq!(idx.point(&[&Value::Int(9)]).len(), 1);
        }
    }

    #[test]
    fn remove_journaled_and_replayed() {
        let dir = LocalDir::temp("eng7").unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("m");
            let r = eng.insert("m", &doc(1, 1)).unwrap();
            eng.insert("m", &doc(2, 2)).unwrap();
            eng.remove("m", r).unwrap();
            eng.sync().unwrap();
        }
        let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("m").docs, 1);
        assert!(eng.fetch("m", 0).is_none());
    }

    #[test]
    fn torn_journal_tail_is_tolerated() {
        let dir = LocalDir::temp("eng8").unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("m");
            eng.insert("m", &doc(1, 1)).unwrap();
            eng.sync().unwrap();
        }
        // Append a torn record: length prefix promising more bytes.
        {
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new()
                .append(true)
                .open(std::path::Path::new(&root).join("journal.wal"))
                .unwrap();
            f.write_all(&100u32.to_le_bytes()).unwrap();
            f.write_all(&[1, 1, b'm']).unwrap(); // incomplete
        }
        let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("m").docs, 1);
    }

    #[test]
    fn insert_many_is_one_frame_and_recovers() {
        let dir = LocalDir::temp("eng10").unwrap();
        let root = dir.describe();
        let docs: Vec<Document> = (0..10).map(|t| doc(t, t % 3)).collect();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("m");
            eng.create_index("m", IndexSpec::single("node_id")).unwrap();
            let rids = eng.insert_many("m", &docs).unwrap();
            assert_eq!(rids.len(), 10);
            assert_eq!(eng.stats("m").docs, 10);

            // Batched framing must be strictly cheaper than ten
            // individual insert frames.
            let (mut single, _) = temp_engine("eng10b", true, false);
            single.create_collection("m");
            for d in &docs {
                single.insert("m", d).unwrap();
            }
            assert!(
                eng.pending_journal_bytes() < single.pending_journal_bytes(),
                "batch frame {} >= individual frames {}",
                eng.pending_journal_bytes(),
                single.pending_journal_bytes()
            );
            eng.sync().unwrap();
            // Drop without checkpoint = crash after group commit.
        }
        let mut eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("m").docs, 10);
        assert_eq!(eng.fetch("m", 7).unwrap().get_i64("ts"), Some(7));
        // Index specs are not journaled (only checkpointed); rebuild and
        // verify entries, then check rid allocation continues past the
        // replayed batch.
        eng.create_index("m", IndexSpec::single("node_id")).unwrap();
        let idx = eng.index("m", "node_id_1").unwrap();
        assert_eq!(idx.point(&[&Value::Int(0)]).len(), 4); // nodes 0,3,6,9
        let rid = eng.insert("m", &doc(99, 9)).unwrap();
        assert_eq!(rid, 10);
    }

    #[test]
    fn unsynced_batch_is_lost_whole_on_crash() {
        let dir = LocalDir::temp("eng12").unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("m");
            eng.insert_many("m", &[doc(1, 1)]).unwrap();
            eng.sync().unwrap();
            eng.insert_many("m", &(0..4).map(|t| doc(10 + t, 2)).collect::<Vec<_>>())
                .unwrap();
            // No sync: the whole second batch is buffered only.
            assert!(eng.pending_journal_bytes() > 0);
        }
        let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("m").docs, 1);
    }

    #[test]
    fn torn_batched_frame_is_discarded_whole() {
        // Build a real batched journal frame in a scratch engine.
        let scratch = LocalDir::temp("eng13-frame").unwrap();
        let scratch_root = scratch.describe();
        {
            let mut eng = Engine::open(Box::new(scratch), true, false).unwrap();
            eng.create_collection("m");
            let batch: Vec<Document> = (100..103).map(|t| doc(t, 1)).collect();
            eng.insert_many("m", &batch).unwrap();
            eng.sync().unwrap();
        }
        let frame =
            std::fs::read(std::path::Path::new(&scratch_root).join("journal.wal")).unwrap();

        // Base journal: one synced batch of 5 documents.
        let base_dir = LocalDir::temp("eng13-base").unwrap();
        let base_root = base_dir.describe();
        {
            let mut eng = Engine::open(Box::new(base_dir), true, false).unwrap();
            eng.create_collection("m");
            eng.insert_many("m", &(0..5).map(|t| doc(t, 0)).collect::<Vec<_>>())
                .unwrap();
            eng.sync().unwrap();
        }
        let base = std::fs::read(std::path::Path::new(&base_root).join("journal.wal")).unwrap();

        // Scenario A — the second batch's frame was fully written before
        // the crash: it replays atomically (5 + 3 docs).
        {
            let dir = LocalDir::temp("eng13-a").unwrap();
            let root = dir.describe();
            let mut bytes = base.clone();
            bytes.extend_from_slice(&frame);
            std::fs::write(std::path::Path::new(&root).join("journal.wal"), &bytes).unwrap();
            let eng =
                Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
            assert_eq!(eng.stats("m").docs, 8);
            assert_eq!(eng.fetch("m", 5).unwrap().get_i64("ts"), Some(100));
        }

        // Scenario B — killed mid-batch: only a prefix of the frame hit
        // the journal. The torn frame must be dropped in full; none of
        // its documents may replay.
        for cut in [1usize, 7, frame.len() - 1] {
            let dir = LocalDir::temp(&format!("eng13-b{cut}")).unwrap();
            let root = dir.describe();
            let mut bytes = base.clone();
            bytes.extend_from_slice(&frame[..cut]);
            std::fs::write(std::path::Path::new(&root).join("journal.wal"), &bytes).unwrap();
            let eng =
                Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
            assert_eq!(eng.stats("m").docs, 5, "cut={cut}: torn batch must not replay");
        }
    }

    #[test]
    fn remove_decode_failure_leaves_collection_consistent() {
        let mut c = Collection::new();
        c.records.insert(0, vec![0xFF, 0xEE]); // not a decodable document
        c.bytes = 2;
        assert!(c.remove(0).is_err());
        assert_eq!(c.bytes, 2, "byte accounting must be untouched");
        assert!(c.records.contains_key(&0), "record must not be stranded");
    }

    #[test]
    fn journaling_disabled_skips_wal() {
        let (mut eng, root) = temp_engine("eng9", false, false);
        eng.create_collection("m");
        eng.insert("m", &doc(1, 1)).unwrap();
        eng.sync().unwrap();
        assert_eq!(eng.pending_journal_bytes(), 0);
        assert!(!std::path::Path::new(&root).join("journal.wal").exists());
    }
}
