//! Sharding: shard keys, chunks, the config-server metadata state, and
//! the balancer policy.

pub mod balancer;
pub mod chunk;
pub mod config_server;

pub use balancer::{plan_moves, BalancerPolicy};
pub use chunk::{ChunkMap, ShardKey};
pub use config_server::ConfigState;
