//! Shard-local storage: a WiredTiger-like engine (record store + WAL +
//! checkpoints) with ordered secondary indexes, writing through a
//! pluggable [`io::StorageDir`] so shards can sit on the Lustre
//! simulator (live mode) or a plain local directory (tests).
//!
//! The engine owns its on-disk lifecycle: the journal is segmented,
//! checkpoints are generation-numbered and cover a segment watermark,
//! and compaction ([`Engine::maybe_checkpoint`]) keeps steady-state
//! disk use bounded under sustained ingest. Checkpoints are
//! *incremental*: most generations write a delta of the records
//! inserted/removed since the previous one ([`delta`], the `HPCCKPT3`
//! format), and the chain periodically rebases into a fresh full
//! snapshot. The formats and the crash-recovery state machine are
//! specified in `docs/ARCHITECTURE.md`.
//!
//! Reads are MVCC: records and index postings carry `[born, dead)`
//! epoch stamps ([`mvcc`]), a [`StoreReader`] serves snapshot-pinned
//! views from any thread while the single writer keeps committing, and
//! [`Engine::reclaim`] drops dead versions once the oldest open
//! snapshot advances (docs/ARCHITECTURE.md §9).

pub mod delta;
pub mod engine;
pub mod index;
pub mod io;
pub mod mvcc;

pub use engine::{
    AtomicOp, CheckpointStats, CollectionStats, Engine, EngineOptions, ReadView, RecordId,
    RecoveryReport, Snapshot, SnapshotExpired, StoreReader,
};
pub use index::{encode_key, Index, IndexSpec};
pub use io::{LocalDir, StorageDir, StorageFile};
pub use mvcc::{Epoch, SnapshotTracker, LATEST, LIVE};
