//! Seedable PRNGs (no `rand` crate in the vendored set).
//!
//! [`SplitMix64`] is the seeding/stream-splitting generator;
//! [`Pcg32`] (PCG-XSH-RR 64/32) is the workhorse for workload synthesis.
//! Both are deterministic across platforms — corpus generation, property
//! tests, and the DES all rely on reproducible streams.

/// SplitMix64 — tiny, fast, passes BigCrush; ideal for seeding.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32: small state, good statistical quality, streamable.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Construct from a seed and stream id (distinct streams are
    /// independent sequences).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed via SplitMix64 so similar seeds produce unrelated streams.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = sm.next_u64();
        let inc = sm.next_u64();
        Self::new(s, inc)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` (Lemire's multiply-shift with rejection).
    #[inline]
    pub fn next_bounded(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "bound must be positive");
        loop {
            let x = self.next_u32() as u64;
            let m = x * bound as u64;
            let lo = m as u32;
            if lo >= bound || lo >= (bound.wrapping_neg() % bound) {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        assert!(lo < hi, "empty range");
        lo + self.next_bounded(hi - lo)
    }

    /// Uniform in `[lo, hi)` over u64 (used for timestamps).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range");
        let span = hi - lo;
        // Simple modulo fold is fine for span << 2^64.
        lo + self.next_u64() % span
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (no cached spare; called rarely).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Exponential with mean `mean` (DES inter-arrival times).
    pub fn next_exp(&mut self, mean: f64) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 1e-12 {
                return -mean * u.ln();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_bounded(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k << n assumed).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        if k * 3 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all.sort_unstable();
            return all;
        }
        let mut seen = std::collections::BTreeSet::new();
        while seen.len() < k {
            seen.insert(self.next_bounded(n as u32) as usize);
        }
        seen.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_distinct_seeds_diverge() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn pcg_reference_vector() {
        // First outputs of PCG32 with seed=42, stream=54 from the PCG
        // reference implementation (pcg32_srandom(42, 54)).
        let mut rng = Pcg32::new(42, 54);
        let got: Vec<u32> = (0..6).map(|_| rng.next_u32()).collect();
        assert_eq!(
            got,
            vec![0xa15c02b7, 0x7b47f409, 0xba1d3330, 0x83d2f293, 0xbfa4784b, 0xcbed606e]
        );
    }

    #[test]
    fn bounded_is_in_range_and_covers() {
        let mut rng = Pcg32::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.next_bounded(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues reached");
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Pcg32::seeded(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} too far from 0.5");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg32::seeded(11);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.next_gaussian();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut rng = Pcg32::seeded(13);
        let n = 20_000;
        let mean = (0..n).map(|_| rng.next_exp(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.15, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(17);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut rng = Pcg32::seeded(19);
        for (n, k) in [(100, 5), (10, 10), (1000, 400)] {
            let s = rng.sample_indices(n, k);
            assert_eq!(s.len(), k);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| i < n));
        }
    }
}
