//! A1 — the AOT route kernel (HLO via PJRT) vs the scalar Rust baseline
//! on the router's insertMany partitioning hot path.
//!
//! Also reports the route kernel's batch-size sensitivity (fixed
//! invocation overhead vs per-key cost) — the measurement behind the
//! cost model's `route_batch_fixed_ns`/`route_doc_ns`.

use hpcstore::benchkit::{Bench, Report};
use hpcstore::runtime::{fallback, Backend, Kernels};
use hpcstore::util::rng::Pcg32;

fn chunk_table(chunks: usize) -> (Vec<u32>, Vec<i32>) {
    let bounds: Vec<u32> = (1..=chunks as u64)
        .map(|i| ((u32::MAX as u64 + 1) * i / chunks as u64 - 1) as u32)
        .collect();
    let owners: Vec<i32> = (0..chunks).map(|i| (i % 63) as i32).collect();
    (bounds, owners)
}

fn main() {
    let mut rng = Pcg32::seeded(0xA1);
    let keys: Vec<(u32, u32)> = (0..8192)
        .map(|_| (rng.next_bounded(28_000), rng.next_u32()))
        .collect();
    let node: Vec<u32> = keys.iter().map(|k| k.0).collect();
    let ts: Vec<u32> = keys.iter().map(|k| k.1).collect();
    let (bounds, owners) = chunk_table(126); // 63 shards × 2 chunks

    let bench = Bench::default();
    let mut report = Report::new("A1 — route kernel: HLO (PJRT) vs scalar fallback, 8192 keys x 126 chunks");

    let hlo = Kernels::load_or_fallback("artifacts");
    if hlo.backend() == Backend::Hlo {
        for &b in &[512usize, 4096, 8192] {
            report.push(bench.run(&format!("hlo route b={b}"), b as f64, || {
                hlo.route(&node[..b], &ts[..b], &bounds, &owners, 63).unwrap();
            }));
        }
    } else {
        println!("(artifacts missing — HLO rows skipped; run `make artifacts`)");
    }

    let fb = Kernels::fallback();
    for &b in &[512usize, 4096, 8192] {
        report.push(bench.run(&format!("scalar route b={b}"), b as f64, || {
            fb.route(&node[..b], &ts[..b], &bounds, &owners, 63).unwrap();
        }));
    }

    // Raw fallback internals for the roofline discussion.
    report.push(bench.run("fnv1a+bsearch only b=8192", 8192.0, || {
        std::hint::black_box(fallback::route_batch(&node, &ts, &bounds, &owners, 63));
    }));
    report.print();
}
