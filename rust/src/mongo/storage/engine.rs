//! WiredTiger-like storage engine: record store + write-ahead journal +
//! checkpoints, multiplexing any number of collections over one
//! [`StorageDir`].
//!
//! Write path: encode document → append journal record (durable at the
//! next group-commit [`Engine::sync`]) → insert into the in-memory
//! record store → update secondary indexes. [`Engine::checkpoint`]
//! persists everything in memory — a full snapshot on generation 1 and
//! on chain rebases, an incremental *delta* otherwise (optionally
//! LZSS-compressed) — publishes it by atomic rename, rotates to a fresh
//! journal segment, and truncates the segments it covers;
//! [`Engine::open`] recovers base snapshot + delta-chain fold +
//! tail-segment replay, so a shard restarted by a later batch job
//! resumes from its Lustre directory — the paper's central persistence
//! story — while its on-disk footprint stays bounded.
//!
//! # MVCC snapshot reads
//!
//! The in-memory state (`Store`: collections, records, indexes) lives
//! behind one `RwLock`; everything on the durability side (journal
//! buffer, segment handles, checkpoint counters) stays outside it, so a
//! group-commit fsync never blocks readers. Every record and index
//! posting carries `[born, dead)` epoch stamps ([`super::mvcc`]); each
//! mutating engine call commits under one fresh epoch, making a whole
//! batch/migration publish visible atomically. A [`StoreReader`] —
//! cheaply cloneable into reader threads — opens [`Snapshot`] handles
//! that pin the committed epoch and serves [`ReadView`]s evaluated at
//! that epoch, while removals only *mark* versions dead.
//! [`Engine::reclaim`] physically drops dead versions once the oldest
//! open snapshot (bounded by [`EngineOptions::snapshot_retention`]) has
//! advanced past them; a snapshot that outlives retention fails with
//! [`SnapshotExpired`] — a clean, retryable error — instead of reading
//! a half-reclaimed state.
//!
//! # Storage lifecycle
//!
//! The journal is a sequence of *segments*, `journal-NNNNNN.wal`, with a
//! monotonically increasing sequence number. The engine appends to one
//! open segment and rotates to the next once the segment reaches
//! [`EngineOptions::segment_bytes`]. Every checkpoint carries a
//! *generation* number and the highest segment sequence it covers; on
//! recovery, segments at or below the covered watermark are skipped (and
//! deleted, finishing any truncation a crash interrupted), so replay
//! cost is proportional to the journal *tail*, not to total writes.
//! [`Engine::maybe_checkpoint`] compacts once
//! [`EngineOptions::checkpoint_bytes`] of journal have been durably
//! written since the last checkpoint — the shard server calls it after
//! every group commit, which keeps steady-state disk use at most one
//! threshold plus one segment (or plus the largest single group-commit
//! frame when a frame exceeds the segment size: a frame is atomic, so
//! the overshoot of the frame that crosses the threshold can never be
//! split away). A pre-rotation single-file `journal.wal`
//! is still replayed (after the checkpoint, before any segment) and is
//! removed by the next checkpoint.
//!
//! # Incremental (delta) checkpoints
//!
//! A full snapshot of the live set costs O(live data) no matter how
//! little changed, so sustained ingest over a large store would pay an
//! ever-growing compaction bill. Instead, only generation 1 (and every
//! *rebase*, below) writes a full snapshot (`store.ckpt`); other
//! generations write a **delta** (`delta-NNNNNN.ckpt`) carrying just
//! the records inserted/removed since the previous generation, tracked
//! per collection in memory. Once the chain reaches
//! [`EngineOptions::full_checkpoint_chain`] deltas, the next checkpoint
//! *rebases*: it writes a fresh full snapshot and deletes the
//! superseded chain, bounding both recovery fold work and the chain's
//! disk footprint. Recovery reconstructs state by folding base + delta
//! chain in generation order, then replaying the journal tail.
//!
//! # On-disk formats
//!
//! Journal record: `u32 len | u8 op | u8 coll_len | coll | payload`,
//! op 1 = insert(doc bytes), op 2 = remove(rid u64 + doc bytes for index
//! maintenance), op 3 = insert_many(u32 count, then per document
//! `u32 len | doc bytes`), op 4 = remove_many(u32 count, then rids
//! only — the chunk-migration range delete), op 5 = move_many(dst
//! name, then per record rid + doc bytes; header coll = source — the
//! migration publish), op 6 = update_many(u32 count, then per record
//! `u64 old_rid | u32 len | new doc bytes` — the CRUD overwrite; replay
//! kills the old version and installs the replacement under a fresh
//! rid), op 7 = delete_many(u32 count, then rids only — the CRUD
//! delete, distinct from op 4 so client deletes and migration range
//! deletes stay distinguishable). Each multi-record op is one frame:
//! recovery replays it atomically or — when the frame is torn by a
//! mid-batch crash — discards it in full, never half-applied.
//!
//! Checkpoints use the `HPCCKPT3` header (see [`super::delta`]):
//! magic, kind (full/delta), generation, base generation, covered
//! segment seq, compressed flag, body. `store.ckpt` is always a full
//! snapshot; `delta-NNNNNN.ckpt` files are the chain on top of it. The
//! legacy `HPCCKPT2` (no kind/base fields) and `HPCCKPT1` (no
//! generation/segment fields) headers still load, so a pre-delta store
//! opens and upgrades in place. See `docs/ARCHITECTURE.md` for the
//! full byte-level layouts and the crash-recovery state machine.

use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};
use std::sync::{Arc, RwLock, RwLockReadGuard, RwLockWriteGuard};

use anyhow::{bail, Context, Result};

use super::delta::{self, DeltaColl, HeaderV3};
use super::index::{Index, IndexSpec};
use super::io::{StorageDir, StorageFile};
use super::mvcc::{visible, Epoch, SnapshotTracker, LATEST, LIVE};
use crate::mongo::bson::Document;
use crate::util::compress;

/// Record identifier within a collection.
pub type RecordId = u64;

/// Pre-rotation single-file journal name (replayed for migration,
/// removed by the next checkpoint).
const JOURNAL_LEGACY: &str = "journal.wal";
/// Checkpoint file name.
const CKPT: &str = "store.ckpt";
/// Staging name [`StorageDir::write_atomic`] uses for [`CKPT`]; a crash
/// during the checkpoint write leaves this behind and recovery discards
/// it.
const CKPT_TMP: &str = "store.ckpt.tmp";
const OP_INSERT: u8 = 1;
const OP_REMOVE: u8 = 2;
const OP_INSERT_MANY: u8 = 3;
/// Batched remove (chunk-migration source delete): one atomic frame for
/// a whole key range, so a kill can never half-delete a chunk.
const OP_REMOVE_MANY: u8 = 4;
/// Cross-collection move (migration publish): remove from the source
/// collection and insert into the destination in one atomic frame, so
/// replay never sees the records in both collections or in neither.
const OP_MOVE_MANY: u8 = 5;
/// Batched overwrite (the CRUD update path): per record the old rid and
/// the full new document in one atomic frame. The old version is killed
/// and the replacement inserted under a fresh rid at one epoch, so a
/// pinned snapshot sees either every pre-update version or every
/// post-update one, never a half-applied batch.
const OP_UPDATE_MANY: u8 = 6;
/// Batched CRUD delete: rids only, one atomic frame per `delete_many`
/// call. Same payload shape as [`OP_REMOVE_MANY`] but a distinct opcode
/// so the journal (and the crash matrix) can tell a client-driven
/// delete from a migration range delete.
const OP_DELETE_MANY: u8 = 7;
/// Multi-collection atomic frame (replication): a sequence of
/// insert/update/remove legs — typically a data op plus the `__oplog`
/// entry describing it, or a hard-state write to `__raft` — journaled
/// as **one** frame and applied at **one** MVCC epoch, so replay and
/// snapshots can never see the data op without its oplog entry or vice
/// versa.
const OP_MULTI: u8 = 8;

/// Below this batch size, per-index maintenance runs inline: spawning
/// scoped threads costs more than the index inserts they would cover.
const INDEX_PARALLEL_MIN_DOCS: usize = 256;
/// Legacy checkpoint magic: `magic | u8 compressed | body`.
const CKPT_MAGIC_V1: &[u8; 8] = b"HPCCKPT1";
/// Legacy pre-delta magic: `magic | u64 generation | u64 covered_seq |
/// u8 compressed | body`. Still loaded (a v2 store upgrades in place);
/// never written — the current header is [`delta::MAGIC_V3`].
const CKPT_MAGIC_V2: &[u8; 8] = b"HPCCKPT2";

/// File name of journal segment `seq`.
fn segment_name(seq: u64) -> String {
    format!("journal-{seq:06}.wal")
}

/// Parse a segment file name back to its sequence number (`None` for
/// anything else, including the legacy `journal.wal`).
fn parse_segment_seq(name: &str) -> Option<u64> {
    name.strip_prefix("journal-")?.strip_suffix(".wal")?.parse().ok()
}

/// One leg of an [`Engine::apply_atomic`] frame. Legs may target
/// different collections; the whole frame journals as one [`OP_MULTI`]
/// record and applies at one MVCC epoch.
#[derive(Clone, Debug)]
pub enum AtomicOp {
    /// Append documents (fresh rids).
    Insert { coll: String, docs: Vec<Document> },
    /// Overwrite live records: each `(old_rid, new_doc)` kills the old
    /// version and installs the replacement under a fresh rid, exactly
    /// like [`Engine::update_many`].
    Update {
        coll: String,
        updates: Vec<(RecordId, Document)>,
    },
    /// Remove live records by rid, exactly like [`Engine::delete_many`].
    Remove { coll: String, rids: Vec<RecordId> },
}

impl AtomicOp {
    fn coll(&self) -> &str {
        match self {
            AtomicOp::Insert { coll, .. }
            | AtomicOp::Update { coll, .. }
            | AtomicOp::Remove { coll, .. } => coll,
        }
    }

    /// Leg discriminant inside an [`OP_MULTI`] frame.
    fn kind(&self) -> u8 {
        match self {
            AtomicOp::Insert { .. } => 0,
            AtomicOp::Update { .. } => 1,
            AtomicOp::Remove { .. } => 2,
        }
    }
}

/// Storage-lifecycle knobs for one engine.
#[derive(Clone, Debug)]
pub struct EngineOptions {
    /// Write-ahead journaling (off = in-memory until checkpoint).
    pub journal: bool,
    /// LZSS-compress checkpoint bodies.
    pub compress_checkpoints: bool,
    /// Compact ([`Engine::maybe_checkpoint`]) once this many journal
    /// bytes are durable since the last checkpoint. `0` = manual
    /// checkpoints only (the pre-lifecycle behaviour).
    pub checkpoint_bytes: u64,
    /// Target number of journal segments per checkpoint interval; the
    /// open segment rotates every `checkpoint_bytes / journal_segments`
    /// bytes so truncation reclaims space in bounded pieces.
    pub journal_segments: u32,
    /// Incremental checkpoints: maximum delta generations per chain.
    /// After this many deltas the next checkpoint *rebases* — writes a
    /// full snapshot and deletes the superseded chain. `0` = every
    /// checkpoint is a full snapshot (the pre-delta behaviour).
    pub full_checkpoint_chain: u32,
    /// Snapshot retention in epochs: [`Engine::reclaim`] expires open
    /// snapshots pinned more than this many commits behind the current
    /// epoch (their next use fails with [`SnapshotExpired`]), bounding
    /// how much dead-version garbage a stalled cursor can hold in
    /// memory. `0` = unbounded — versions live as long as any snapshot
    /// that can see them.
    pub snapshot_retention: u64,
}

impl Default for EngineOptions {
    fn default() -> Self {
        Self {
            journal: true,
            compress_checkpoints: false,
            checkpoint_bytes: 0,
            journal_segments: 4,
            full_checkpoint_chain: 8,
            snapshot_retention: 0,
        }
    }
}

impl EngineOptions {
    /// Rotation threshold for the open journal segment. Unbounded when
    /// auto-compaction is off: a single segment then behaves exactly
    /// like the pre-lifecycle single-file journal.
    pub fn segment_bytes(&self) -> u64 {
        if self.checkpoint_bytes == 0 {
            u64::MAX
        } else {
            (self.checkpoint_bytes / self.journal_segments.max(1) as u64).max(1)
        }
    }
}

/// What one [`Engine::checkpoint`] did (admin-command reply, metrics).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CheckpointStats {
    /// Generation number of the checkpoint just written.
    pub generation: u64,
    /// Size of the file written this generation (full snapshot or
    /// delta), after optional compression.
    pub checkpoint_bytes: u64,
    /// Size of the delta file written this generation; `0` when this
    /// generation wrote a full snapshot. The headline scaling claim:
    /// steady-state, this tracks new writes, not the live set.
    pub delta_bytes: u64,
    /// Whether this generation wrote a full snapshot (generation 1 or a
    /// chain rebase) rather than a delta.
    pub full: bool,
    /// Delta generations on top of the on-disk full snapshot *after*
    /// this checkpoint (`0` right after a rebase).
    pub chain_len: u64,
    /// Journal files deleted because the checkpoint covers them
    /// (segments plus any legacy `journal.wal`).
    pub segments_truncated: u64,
    /// On-disk journal bytes reclaimed by the truncation.
    pub journal_bytes_truncated: u64,
}

/// What the last [`Engine::open`] replayed (recovery benchmarks, crash
/// tests).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    /// Generation of the newest checkpoint recovered — base full
    /// snapshot plus every folded delta (0 = none on disk).
    pub checkpoint_generation: u64,
    /// Delta checkpoints folded on top of the base snapshot.
    pub deltas_folded: u64,
    /// On-disk bytes of the folded delta chain.
    pub delta_bytes_folded: u64,
    /// Journal files replayed (tail segments plus any legacy journal).
    pub segments_replayed: u64,
    /// Segments skipped — and deleted — because the checkpoint already
    /// covers them (a crash interrupted their truncation).
    pub segments_skipped: u64,
    /// Complete journal frames applied.
    pub frames_replayed: u64,
    /// Journal bytes applied (excludes any torn tail).
    pub bytes_replayed: u64,
}

/// Per-collection statistics.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CollectionStats {
    /// Live documents.
    pub docs: u64,
    /// Encoded bytes of the live documents.
    pub bytes: u64,
    /// Entries across all secondary indexes.
    pub index_entries: u64,
}

/// One record version: the encoded document plus its `[born, dead)`
/// visibility window. Record ids are never reused, so a rid has exactly
/// one version — no update chains — and a *remove* only stamps `dead`,
/// leaving the bytes readable by older snapshots until
/// [`Engine::reclaim`] drops them.
struct VRecord {
    born: Epoch,
    dead: Epoch,
    bytes: Vec<u8>,
}

struct Collection {
    records: BTreeMap<RecordId, VRecord>,
    next_rid: RecordId,
    indexes: Vec<Index>,
    /// Encoded bytes of the *live* records (dead-but-retained versions
    /// are garbage, not working set).
    bytes: u64,
    /// Live record count (`records.len()` includes dead versions).
    live: u64,
    /// Records inserted since the last checkpoint — the upsert half of
    /// the next delta. Checkpoint-chain loading bypasses this (those
    /// records are already persistent); live writes and journal replay
    /// (durable-but-uncheckpointed work) both feed it.
    dirty: BTreeSet<RecordId>,
    /// Records removed since the last checkpoint that existed *at* the
    /// last checkpoint — the remove half of the next delta. A record
    /// born and removed within one interval nets out of both sets.
    tombstones: BTreeSet<RecordId>,
    /// Dead versions awaiting reclamation, in kill order — epochs only
    /// grow, so the queue is sorted by death epoch and
    /// [`Collection::reclaim`] pops a prefix.
    garbage: VecDeque<(Epoch, RecordId)>,
}

impl Collection {
    fn new() -> Self {
        Self {
            records: BTreeMap::new(),
            next_rid: 0,
            indexes: Vec::new(),
            bytes: 0,
            live: 0,
            dirty: BTreeSet::new(),
            tombstones: BTreeSet::new(),
            garbage: VecDeque::new(),
        }
    }

    /// Install a whole batch: allocate rids and record bytes serially
    /// (the record store is the ordering authority), then maintain each
    /// secondary index over the full batch. With several indexes and a
    /// large batch the per-index work runs on scoped threads — the
    /// indexes are independent structures, so the maintenance that used
    /// to be sequential per document parallelizes without locking, and
    /// the result is bit-identical to the inline path.
    fn insert_batch(
        &mut self,
        docs: &[Document],
        encoded: Vec<Vec<u8>>,
        born: Epoch,
    ) -> Vec<RecordId> {
        let mut rids = Vec::with_capacity(docs.len());
        for enc in encoded {
            let rid = self.next_rid;
            self.next_rid += 1;
            self.bytes += enc.len() as u64;
            self.live += 1;
            self.records.insert(rid, VRecord { born, dead: LIVE, bytes: enc });
            self.dirty.insert(rid);
            rids.push(rid);
        }
        if self.indexes.len() > 1 && docs.len() >= INDEX_PARALLEL_MIN_DOCS {
            let rids = &rids;
            std::thread::scope(|s| {
                for idx in self.indexes.iter_mut() {
                    s.spawn(move || {
                        for (doc, rid) in docs.iter().zip(rids) {
                            idx.insert_at(doc, *rid, born);
                        }
                    });
                }
            });
        } else {
            for idx in &mut self.indexes {
                for (doc, rid) in docs.iter().zip(&rids) {
                    idx.insert_at(doc, *rid, born);
                }
            }
        }
        rids
    }

    fn insert_decoded(&mut self, doc: &Document, encoded: Vec<u8>, born: Epoch) -> RecordId {
        let rid = self.next_rid;
        self.next_rid += 1;
        self.bytes += encoded.len() as u64;
        self.live += 1;
        self.records.insert(rid, VRecord { born, dead: LIVE, bytes: encoded });
        self.dirty.insert(rid);
        for idx in &mut self.indexes {
            idx.insert_at(doc, rid, born);
        }
        rid
    }

    /// Logically remove a record: stamp its version dead at `epoch` and
    /// queue it for reclamation. The bytes stay in place — snapshots
    /// pinned before `epoch` keep reading them — but they leave the
    /// live accounting immediately.
    fn remove(&mut self, rid: RecordId, epoch: Epoch) -> Result<Document> {
        // Decode before mutating: if the record bytes are corrupt, the
        // byte accounting and index state must be left untouched.
        let rec = self
            .records
            .get(&rid)
            .filter(|r| r.dead == LIVE)
            .ok_or_else(|| anyhow::anyhow!("no record {rid}"))?;
        let doc = Document::decode(&rec.bytes)?;
        let len = rec.bytes.len() as u64;
        // lint: allow(panic, the get above proved the rid is present)
        self.records.get_mut(&rid).expect("present above").dead = epoch;
        self.bytes -= len;
        self.live -= 1;
        if !self.dirty.remove(&rid) {
            self.tombstones.insert(rid);
        }
        for idx in &mut self.indexes {
            idx.kill(&doc, rid, epoch);
        }
        self.garbage.push_back((epoch, rid));
        Ok(doc)
    }

    /// Physically drop every dead version with `dead <= floor` (no open
    /// or future snapshot can see them), pruning their index postings.
    /// Returns how many versions were reclaimed.
    fn reclaim(&mut self, floor: Epoch) -> u64 {
        let mut reclaimed = 0u64;
        while let Some(&(dead, rid)) = self.garbage.front() {
            if dead > floor {
                break;
            }
            self.garbage.pop_front();
            if let Some(rec) = self.records.remove(&rid) {
                if let Ok(doc) = Document::decode(&rec.bytes) {
                    for idx in &mut self.indexes {
                        idx.prune(&doc, rid);
                    }
                }
                reclaimed += 1;
            }
        }
        reclaimed
    }

    /// Apply a checkpoint-chain upsert during recovery fold: install
    /// `encoded` at `rid` without touching rid allocation or delta
    /// tracking (folded records are already persistent). Recovery is
    /// single-threaded with no snapshots open, so folds are physical
    /// and everything is born at epoch 0.
    fn apply_upsert(&mut self, rid: RecordId, encoded: Vec<u8>) -> Result<()> {
        let doc = Document::decode(&encoded)?;
        if let Some(old) = self.records.remove(&rid) {
            // Defensive: chains never legitimately overwrite a rid, but
            // if one does the accounting must stay exact.
            if old.dead == LIVE {
                self.bytes -= old.bytes.len() as u64;
                self.live -= 1;
            }
            if let Ok(old_doc) = Document::decode(&old.bytes) {
                for idx in &mut self.indexes {
                    idx.remove(&old_doc, rid);
                }
            }
        }
        self.bytes += encoded.len() as u64;
        self.live += 1;
        self.records.insert(rid, VRecord { born: 0, dead: LIVE, bytes: encoded });
        for idx in &mut self.indexes {
            idx.insert(&doc, rid);
        }
        Ok(())
    }

    /// Apply a checkpoint-chain remove during recovery fold (no delta
    /// tracking; missing rids are tolerated — the chain is idempotent
    /// over states a crash may have left half-visible).
    fn apply_remove(&mut self, rid: RecordId) {
        if let Some(rec) = self.records.remove(&rid) {
            if rec.dead == LIVE {
                self.bytes -= rec.bytes.len() as u64;
                self.live -= 1;
            }
            if let Ok(doc) = Document::decode(&rec.bytes) {
                for idx in &mut self.indexes {
                    idx.remove(&doc, rid);
                }
            }
        }
    }
}

/// The in-memory half of the engine — everything a read needs — behind
/// one `RwLock`. Mutating engine calls hold the write lock only across
/// the in-memory apply (journaling, fsync, and checkpoint file writes
/// all happen outside it).
#[derive(Default)]
struct Store {
    /// Last committed epoch. Every mutating engine call commits as
    /// `epoch + 1` and advances this at the end, so a snapshot pinned
    /// at `epoch` never sees a half-applied batch.
    epoch: Epoch,
    /// Snapshots pinned strictly below this are expired: reclamation
    /// may have dropped versions they could see, so their next use
    /// fails with [`SnapshotExpired`] instead of reading a torn state.
    floor: Epoch,
    collections: HashMap<String, Collection>,
}

impl Store {
    fn reclaim(&mut self, floor: Epoch) -> u64 {
        let mut reclaimed = 0u64;
        for c in self.collections.values_mut() {
            reclaimed += c.reclaim(floor);
        }
        self.floor = self.floor.max(floor);
        reclaimed
    }

    /// Dead versions still queued for reclamation.
    fn garbage_len(&self) -> u64 {
        self.collections.values().map(|c| c.garbage.len() as u64).sum()
    }
}

fn read_store(store: &RwLock<Store>) -> RwLockReadGuard<'_, Store> {
    match store.read() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

fn write_store(store: &RwLock<Store>) -> RwLockWriteGuard<'_, Store> {
    match store.write() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

fn create_collection_in(store: &mut Store, name: &str) {
    store
        .collections
        .entry(name.to_string())
        .or_insert_with(Collection::new);
}

/// Create a secondary index (idempotent), backfilling from existing
/// records. The backfill copies each record's `[born, dead)` stamps —
/// including dead-but-retained versions — so a snapshot query planned
/// over a freshly created index sees exactly the records a table scan
/// at its epoch would.
fn create_index_in(store: &mut Store, coll: &str, spec: IndexSpec) -> Result<()> {
    create_collection_in(store, coll);
    // lint: allow(panic, create_collection_in on the line above inserts the entry)
    let c = store.collections.get_mut(coll).unwrap();
    if c.indexes.iter().any(|i| i.spec == spec) {
        return Ok(());
    }
    let mut idx = Index::new(spec);
    for (rid, rec) in &c.records {
        idx.insert_version(&Document::decode(&rec.bytes)?, *rid, rec.born, rec.dead);
    }
    c.indexes.push(idx);
    Ok(())
}

/// A snapshot outlived [`EngineOptions::snapshot_retention`]: the
/// versions it could see may be reclaimed, so the read must be retried
/// on a fresh snapshot. Carries the pinned epoch and the floor that
/// expired it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SnapshotExpired {
    pub at: Epoch,
    pub floor: Epoch,
}

impl std::fmt::Display for SnapshotExpired {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "snapshot at epoch {} expired (reclaim floor {})",
            self.at, self.floor
        )
    }
}

impl std::error::Error for SnapshotExpired {}

/// An open snapshot: a pinned commit epoch. Holding one keeps every
/// version visible at that epoch reclaimable only after the handle
/// drops (or retention expires it). Cheap — no data is copied; the pin
/// is an entry in the shared [`SnapshotTracker`].
pub struct Snapshot {
    at: Epoch,
    tracker: Arc<SnapshotTracker>,
}

impl Snapshot {
    /// The pinned commit epoch this snapshot reads at.
    pub fn at(&self) -> Epoch {
        self.at
    }
}

impl Drop for Snapshot {
    fn drop(&mut self) {
        self.tracker.unpin(self.at);
    }
}

/// A read handle on the engine's store, cloneable into reader threads.
/// Opens [`Snapshot`]s and serves [`ReadView`]s; never blocks on the
/// writer's journaling or fsync, only on its brief in-memory applies.
#[derive(Clone)]
pub struct StoreReader {
    store: Arc<RwLock<Store>>,
    tracker: Arc<SnapshotTracker>,
}

impl StoreReader {
    /// Open a snapshot pinned at the last committed epoch.
    pub fn snapshot(&self) -> Snapshot {
        let at = read_store(&self.store).epoch;
        self.tracker.pin(at);
        Snapshot { at, tracker: Arc::clone(&self.tracker) }
    }

    /// A view of the store frozen at `snap`'s epoch. Fails with
    /// [`SnapshotExpired`] once retention has let reclamation advance
    /// past the snapshot — the caller retries on a fresh one.
    pub fn view(&self, snap: &Snapshot) -> Result<ReadView<'_>, SnapshotExpired> {
        let guard = read_store(&self.store);
        if snap.at < guard.floor {
            return Err(SnapshotExpired { at: snap.at, floor: guard.floor });
        }
        Ok(ReadView { guard, at: snap.at })
    }

    /// A view of the latest committed state (no pin; the view's guard
    /// alone keeps it stable).
    pub fn latest(&self) -> ReadView<'_> {
        ReadView { guard: read_store(&self.store), at: LATEST }
    }

    /// Open snapshots across all handles (the `shard.snapshots_open`
    /// gauge).
    pub fn snapshots_open(&self) -> u64 {
        self.tracker.open_count()
    }
}

/// A borrowed, immutable view of the store evaluated at one epoch —
/// the read path's working surface. Holds the store's read lock: keep
/// views scoped to one served batch, not across waits.
pub struct ReadView<'a> {
    guard: RwLockReadGuard<'a, Store>,
    at: Epoch,
}

impl ReadView<'_> {
    /// The epoch this view evaluates visibility at ([`LATEST`] for a
    /// latest-state view) — pass it to the index `_at` methods so
    /// index-driven plans see exactly this view's record set.
    pub fn at(&self) -> Epoch {
        self.at
    }

    /// Encoded bytes of one record, if visible at this view's epoch.
    pub fn fetch_raw(&self, coll: &str, rid: RecordId) -> Option<&[u8]> {
        let rec = self.guard.collections.get(coll)?.records.get(&rid)?;
        visible(rec.born, rec.dead, self.at).then(|| rec.bytes.as_slice())
    }

    /// Look up a secondary index by name. Postings are epoch-stamped;
    /// combine with [`ReadView::at`] on the `_at` query methods.
    pub fn index(&self, coll: &str, name: &str) -> Option<&Index> {
        self.guard
            .collections
            .get(coll)?
            .indexes
            .iter()
            .find(|i| i.spec.name == name)
    }

    /// Raw scan in record-id order starting *after* `after` (exclusive;
    /// `None` = from the beginning), yielding only records visible at
    /// this view's epoch.
    pub fn scan_raw_from<'b>(
        &'b self,
        coll: &str,
        after: Option<RecordId>,
    ) -> Box<dyn Iterator<Item = (RecordId, &'b [u8])> + 'b> {
        use std::ops::Bound;
        let lo = match after {
            Some(r) => Bound::Excluded(r),
            None => Bound::Unbounded,
        };
        let at = self.at;
        match self.guard.collections.get(coll) {
            Some(c) => Box::new(
                c.records
                    .range((lo, Bound::Unbounded))
                    .filter(move |(_, rec)| visible(rec.born, rec.dead, at))
                    .map(|(rid, rec)| (*rid, rec.bytes.as_slice())),
            ),
            None => Box::new(std::iter::empty()),
        }
    }

    /// Documents visible at this view's epoch (`stats().docs` of the
    /// snapshot world).
    pub fn doc_count(&self, coll: &str) -> u64 {
        match self.guard.collections.get(coll) {
            Some(c) if self.at == LATEST => c.live,
            Some(c) => c
                .records
                .values()
                .filter(|rec| visible(rec.born, rec.dead, self.at))
                .count() as u64,
            None => 0,
        }
    }
}

/// The storage engine. One writer by design: each shard server thread
/// owns one engine (WiredTiger-style, one cache per `mongod`) and is
/// the only mutator; any number of [`StoreReader`] clones serve
/// snapshot reads concurrently.
pub struct Engine {
    dir: Box<dyn StorageDir>,
    /// The open journal segment (`None` when journaling is off).
    journal: Option<Box<dyn StorageFile>>,
    store: Arc<RwLock<Store>>,
    tracker: Arc<SnapshotTracker>,
    opts: EngineOptions,
    journal_buf: Vec<u8>,
    /// Frames staged in `journal_buf`, not yet durable.
    pending_frames: u64,
    /// Sequence number of the open segment.
    current_seq: u64,
    /// Highest segment sequence the on-disk checkpoint chain covers.
    covered_seq: u64,
    /// Generation of the newest on-disk checkpoint, full or delta
    /// (0 = none yet).
    generation: u64,
    /// Generation of the on-disk *full* snapshot the delta chain builds
    /// on (`generation - base_generation` = chain length).
    base_generation: u64,
    /// On-disk bytes of the live delta chain (rebase resets it).
    chain_bytes: u64,
    /// Journal bytes made durable since the last checkpoint — the
    /// auto-compaction trigger.
    synced_bytes_since_ckpt: u64,
    /// Journal frames made durable since the last checkpoint.
    frames_since_ckpt: u64,
    /// On-disk bytes in live *sealed* segments (the open segment's bytes
    /// are read from its file handle).
    sealed_bytes: u64,
    recovery: RecoveryReport,
}

impl Engine {
    /// Open (or create) an engine on `dir`, recovering any checkpoint +
    /// journal found there. Convenience wrapper over
    /// [`Engine::open_with`] with manual-checkpoint lifecycle defaults.
    pub fn open(
        dir: Box<dyn StorageDir>,
        journal_enabled: bool,
        compress_checkpoints: bool,
    ) -> Result<Self> {
        Self::open_with(
            dir,
            EngineOptions {
                journal: journal_enabled,
                compress_checkpoints,
                ..EngineOptions::default()
            },
        )
    }

    /// Open (or create) an engine with explicit lifecycle options,
    /// recovering checkpoint + journal-tail state from `dir`. The
    /// recovery outcome is readable via [`Engine::recovery_report`].
    pub fn open_with(dir: Box<dyn StorageDir>, opts: EngineOptions) -> Result<Self> {
        let mut eng = Self {
            journal: None,
            dir,
            store: Arc::new(RwLock::new(Store::default())),
            tracker: Arc::new(SnapshotTracker::new()),
            opts,
            journal_buf: Vec::new(),
            pending_frames: 0,
            current_seq: 0,
            covered_seq: 0,
            generation: 0,
            base_generation: 0,
            chain_bytes: 0,
            synced_bytes_since_ckpt: 0,
            frames_since_ckpt: 0,
            sealed_bytes: 0,
            recovery: RecoveryReport::default(),
        };
        eng.recover()?;
        // The open segment is created lazily by the first group commit
        // (see [`Engine::sync`]): an idle open leaves no new file, and
        // replayed segments stay sealed so a later crash can only tear
        // the newest file.
        Ok(eng)
    }

    /// Create a collection if missing.
    pub fn create_collection(&mut self, name: &str) {
        create_collection_in(&mut write_store(&self.store), name);
    }

    /// Create a secondary index (idempotent), backfilling from existing
    /// records.
    pub fn create_index(&mut self, coll: &str, spec: IndexSpec) -> Result<()> {
        create_index_in(&mut write_store(&self.store), coll, spec)
    }

    /// Insert one document. Durable after the next [`Self::sync`].
    pub fn insert(&mut self, coll: &str, doc: &Document) -> Result<RecordId> {
        // Check the collection before journaling: a failed insert must
        // not leave a record in the journal buffer that would
        // materialize on replay.
        if !read_store(&self.store).collections.contains_key(coll) {
            bail!("no collection `{coll}`");
        }
        let encoded = doc.encode();
        if self.opts.journal {
            self.journal_record(OP_INSERT, coll, &encoded);
        }
        let mut store = write_store(&self.store);
        let epoch = store.epoch + 1;
        // lint: allow(panic, the contains_key check at function entry bails first)
        let c = store.collections.get_mut(coll).expect("collection checked above");
        let rid = c.insert_decoded(doc, encoded, epoch);
        store.epoch = epoch;
        Ok(rid)
    }

    /// Insert a whole batch as **one** multi-record journal frame — the
    /// group-commit unit of the bulk write path. Recovery replays the
    /// frame atomically; a frame torn by a mid-batch crash is discarded
    /// in full. Durable after the next [`Self::sync`].
    pub fn insert_many(&mut self, coll: &str, docs: &[Document]) -> Result<Vec<RecordId>> {
        if docs.is_empty() {
            return Ok(Vec::new());
        }
        anyhow::ensure!(docs.len() <= u32::MAX as usize, "insert_many batch too large");
        if !read_store(&self.store).collections.contains_key(coll) {
            bail!("no collection `{coll}`");
        }
        let encoded: Vec<Vec<u8>> = docs.iter().map(Document::encode).collect();
        if self.opts.journal {
            let payload_len = 4 + encoded.iter().map(|e| 4 + e.len()).sum::<usize>();
            let mut payload = Vec::with_capacity(payload_len);
            payload.extend_from_slice(&(docs.len() as u32).to_le_bytes());
            for e in &encoded {
                payload.extend_from_slice(&(e.len() as u32).to_le_bytes());
                payload.extend_from_slice(e);
            }
            self.journal_record(OP_INSERT_MANY, coll, &payload);
        }
        let mut store = write_store(&self.store);
        let epoch = store.epoch + 1;
        // lint: allow(panic, the contains_key check at function entry bails first)
        let c = store.collections.get_mut(coll).expect("collection checked above");
        let rids = c.insert_batch(docs, encoded, epoch);
        store.epoch = epoch;
        Ok(rids)
    }

    /// Remove a whole set of records as **one** multi-record journal
    /// frame — the range-delete unit of chunk migration. Replay applies
    /// the frame atomically (a torn frame is discarded whole), so a
    /// kill can never half-delete a chunk. `rids` must be distinct and
    /// present. Durable after the next [`Self::sync`].
    pub fn remove_many(&mut self, coll: &str, rids: &[RecordId]) -> Result<Vec<Document>> {
        if rids.is_empty() {
            return Ok(Vec::new());
        }
        anyhow::ensure!(rids.len() <= u32::MAX as usize, "remove_many batch too large");
        // Validate (and decode) every record up front: the journal frame
        // and the in-memory mutation must cover exactly the same set, or
        // a mid-batch failure would leave them disagreeing. The frame
        // carries only the rids — unlike OP_REMOVE, no document bodies:
        // replay removes by rid (index maintenance decodes the stored
        // record), so a chunk-sized delete journals a few bytes per
        // document instead of re-journaling the whole chunk at the
        // migration commit instant.
        let mut docs = Vec::with_capacity(rids.len());
        let mut payload = Vec::new();
        payload.extend_from_slice(&(rids.len() as u32).to_le_bytes());
        {
            let store = read_store(&self.store);
            let c = store
                .collections
                .get(coll)
                .ok_or_else(|| anyhow::anyhow!("no collection `{coll}`"))?;
            for &rid in rids {
                let rec = c
                    .records
                    .get(&rid)
                    .filter(|r| r.dead == LIVE)
                    .ok_or_else(|| anyhow::anyhow!("no record {rid}"))?;
                let doc = Document::decode(&rec.bytes)?;
                payload.extend_from_slice(&rid.to_le_bytes());
                docs.push(doc);
            }
        }
        if self.opts.journal {
            self.journal_record(OP_REMOVE_MANY, coll, &payload);
        }
        let mut store = write_store(&self.store);
        let epoch = store.epoch + 1;
        // lint: allow(panic, the collect loop above already resolved every rid in this collection)
        let c = store.collections.get_mut(coll).expect("collection checked above");
        for &rid in rids {
            // lint: allow(panic, every rid was fetched live from this collection above)
            c.remove(rid, epoch).expect("record validated above");
        }
        store.epoch = epoch;
        Ok(docs)
    }

    /// Move records from `src` to `dst` in **one** atomic journal frame
    /// — the publish step of chunk migration: staged documents become
    /// live with no replay state in which they exist in both
    /// collections or in neither. The records are assigned fresh ids in
    /// `dst` (collections have independent rid spaces); the returned
    /// vector is in `rids` order. Durable after the next [`Self::sync`].
    pub fn move_many(
        &mut self,
        src: &str,
        dst: &str,
        rids: &[RecordId],
    ) -> Result<Vec<RecordId>> {
        if rids.is_empty() {
            return Ok(Vec::new());
        }
        anyhow::ensure!(src != dst, "move_many: src and dst are the same collection");
        anyhow::ensure!(rids.len() <= u32::MAX as usize, "move_many batch too large");
        anyhow::ensure!(dst.len() <= u8::MAX as usize, "collection name too long");
        let mut docs = Vec::with_capacity(rids.len());
        let mut encs = Vec::with_capacity(rids.len());
        let mut payload = Vec::new();
        payload.push(dst.len() as u8);
        payload.extend_from_slice(dst.as_bytes());
        payload.extend_from_slice(&(rids.len() as u32).to_le_bytes());
        {
            let store = read_store(&self.store);
            if !store.collections.contains_key(dst) {
                bail!("no collection `{dst}`");
            }
            let c = store
                .collections
                .get(src)
                .ok_or_else(|| anyhow::anyhow!("no collection `{src}`"))?;
            for &rid in rids {
                let rec = c
                    .records
                    .get(&rid)
                    .filter(|r| r.dead == LIVE)
                    .ok_or_else(|| anyhow::anyhow!("no record {rid}"))?;
                let doc = Document::decode(&rec.bytes)?;
                payload.extend_from_slice(&rid.to_le_bytes());
                payload.extend_from_slice(&(rec.bytes.len() as u32).to_le_bytes());
                payload.extend_from_slice(&rec.bytes);
                docs.push(doc);
                encs.push(rec.bytes.clone());
            }
        }
        if self.opts.journal {
            self.journal_record(OP_MOVE_MANY, src, &payload);
        }
        // One epoch for the whole flip: a snapshot either sees every
        // record in `src` or every record in `dst`, never both/neither.
        let mut store = write_store(&self.store);
        let epoch = store.epoch + 1;
        // lint: allow(panic, the collect loop above already resolved every rid in src)
        let c = store.collections.get_mut(src).expect("collection checked above");
        for &rid in rids {
            // lint: allow(panic, every rid was fetched live from src above)
            c.remove(rid, epoch).expect("record validated above");
        }
        // lint: allow(panic, the contains_key(dst) check above bails first)
        let d = store.collections.get_mut(dst).expect("collection checked above");
        let moved = d.insert_batch(&docs, encs, epoch);
        store.epoch = epoch;
        Ok(moved)
    }

    /// Overwrite a whole batch of records as **one** multi-record
    /// journal frame — the CRUD update path. Each `(old_rid, new_doc)`
    /// pair kills the old version (`dead = e`) and installs the
    /// replacement under a freshly allocated rid (`born = e`) at one
    /// shared epoch, so record ids keep exactly one version each and a
    /// pinned snapshot opened before the batch reads only pre-update
    /// versions. Every secondary index (including the compound
    /// `(node_id, ts)` index) gets its kill + insert deltas through the
    /// ordinary `Collection::remove`/`insert_decoded` maintenance.
    /// `old_rid`s must be distinct and live. Returns the fresh rids in
    /// `updates` order. Durable after the next [`Self::sync`].
    pub fn update_many(
        &mut self,
        coll: &str,
        updates: &[(RecordId, Document)],
    ) -> Result<Vec<RecordId>> {
        if updates.is_empty() {
            return Ok(Vec::new());
        }
        anyhow::ensure!(updates.len() <= u32::MAX as usize, "update_many batch too large");
        // Validate every old rid live and encode every replacement under
        // a read guard before journaling: the frame and the in-memory
        // mutation must cover exactly the same set (single writer —
        // nothing invalidates the check in between).
        let mut encoded = Vec::with_capacity(updates.len());
        let mut payload = Vec::new();
        payload.extend_from_slice(&(updates.len() as u32).to_le_bytes());
        {
            let store = read_store(&self.store);
            let c = store
                .collections
                .get(coll)
                .ok_or_else(|| anyhow::anyhow!("no collection `{coll}`"))?;
            let mut seen = BTreeSet::new();
            for (rid, doc) in updates {
                anyhow::ensure!(seen.insert(*rid), "duplicate rid {rid} in update batch");
                c.records
                    .get(rid)
                    .filter(|r| r.dead == LIVE)
                    .ok_or_else(|| anyhow::anyhow!("no record {rid}"))?;
                let enc = doc.encode();
                payload.extend_from_slice(&rid.to_le_bytes());
                payload.extend_from_slice(&(enc.len() as u32).to_le_bytes());
                payload.extend_from_slice(&enc);
                encoded.push(enc);
            }
        }
        if self.opts.journal {
            self.journal_record(OP_UPDATE_MANY, coll, &payload);
        }
        // One epoch for the whole batch: a snapshot sees every old
        // version or every new one, never a half-applied overwrite.
        let mut store = write_store(&self.store);
        let epoch = store.epoch + 1;
        // lint: allow(panic, the validation loop above already resolved every rid in this collection)
        let c = store.collections.get_mut(coll).expect("collection checked above");
        let mut fresh = Vec::with_capacity(updates.len());
        for ((rid, doc), enc) in updates.iter().zip(encoded) {
            // lint: allow(panic, every rid was fetched live from this collection above)
            c.remove(*rid, epoch).expect("record validated above");
            fresh.push(c.insert_decoded(doc, enc, epoch));
        }
        store.epoch = epoch;
        Ok(fresh)
    }

    /// Delete a whole batch of records as **one** multi-record journal
    /// frame — the CRUD delete path. Identical application semantics to
    /// [`Self::remove_many`] (rids-only payload, batch-atomic epoch,
    /// per-index kill deltas) under a distinct opcode, so the journal
    /// tells a client-driven delete from a migration range delete.
    /// `rids` must be distinct and live. Durable after the next
    /// [`Self::sync`].
    pub fn delete_many(&mut self, coll: &str, rids: &[RecordId]) -> Result<Vec<Document>> {
        if rids.is_empty() {
            return Ok(Vec::new());
        }
        anyhow::ensure!(rids.len() <= u32::MAX as usize, "delete_many batch too large");
        let mut docs = Vec::with_capacity(rids.len());
        let mut payload = Vec::new();
        payload.extend_from_slice(&(rids.len() as u32).to_le_bytes());
        {
            let store = read_store(&self.store);
            let c = store
                .collections
                .get(coll)
                .ok_or_else(|| anyhow::anyhow!("no collection `{coll}`"))?;
            for &rid in rids {
                let rec = c
                    .records
                    .get(&rid)
                    .filter(|r| r.dead == LIVE)
                    .ok_or_else(|| anyhow::anyhow!("no record {rid}"))?;
                let doc = Document::decode(&rec.bytes)?;
                payload.extend_from_slice(&rid.to_le_bytes());
                docs.push(doc);
            }
        }
        if self.opts.journal {
            self.journal_record(OP_DELETE_MANY, coll, &payload);
        }
        let mut store = write_store(&self.store);
        let epoch = store.epoch + 1;
        // lint: allow(panic, the collect loop above already resolved every rid in this collection)
        let c = store.collections.get_mut(coll).expect("collection checked above");
        for &rid in rids {
            // lint: allow(panic, every rid was fetched live from this collection above)
            c.remove(rid, epoch).expect("record validated above");
        }
        store.epoch = epoch;
        Ok(docs)
    }

    /// Apply a sequence of insert/update/remove legs — possibly across
    /// collections — as **one** journal frame at **one** MVCC epoch.
    /// This is the replication write unit: a data op plus the `__oplog`
    /// entry describing it commit or vanish together, so recovery never
    /// sees an applied op without its oplog entry (or an entry without
    /// its op). Validation runs against the pre-frame state: every
    /// referenced rid must be live *before* the frame, and a rid may be
    /// referenced at most once per collection across the whole frame.
    /// Returns the freshly allocated rids per leg (insert → new rids,
    /// update → replacement rids, remove → empty). Durable after the
    /// next [`Self::sync`].
    pub fn apply_atomic(&mut self, ops: &[AtomicOp]) -> Result<Vec<Vec<RecordId>>> {
        if ops.is_empty() {
            return Ok(Vec::new());
        }
        anyhow::ensure!(ops.len() <= u32::MAX as usize, "apply_atomic frame too large");
        // Validate every leg and build the frame payload under a read
        // guard before journaling (single writer — nothing invalidates
        // the checks in between). `encoded[i]` keeps leg i's document
        // encodings for the apply stage.
        let mut payload = Vec::new();
        payload.extend_from_slice(&(ops.len() as u32).to_le_bytes());
        let mut encoded: Vec<Vec<Vec<u8>>> = Vec::with_capacity(ops.len());
        {
            let store = read_store(&self.store);
            let mut seen: BTreeMap<&str, BTreeSet<RecordId>> = BTreeMap::new();
            for op in ops {
                let coll = op.coll();
                anyhow::ensure!(coll.len() <= u8::MAX as usize, "collection name too long");
                let c = store
                    .collections
                    .get(coll)
                    .ok_or_else(|| anyhow::anyhow!("no collection `{coll}`"))?;
                let used = seen.entry(coll).or_default();
                payload.push(op.kind());
                payload.push(coll.len() as u8);
                payload.extend_from_slice(coll.as_bytes());
                match op {
                    AtomicOp::Insert { docs, .. } => {
                        anyhow::ensure!(
                            docs.len() <= u32::MAX as usize,
                            "apply_atomic insert leg too large"
                        );
                        payload.extend_from_slice(&(docs.len() as u32).to_le_bytes());
                        let mut encs = Vec::with_capacity(docs.len());
                        for doc in docs {
                            let enc = doc.encode();
                            payload.extend_from_slice(&(enc.len() as u32).to_le_bytes());
                            payload.extend_from_slice(&enc);
                            encs.push(enc);
                        }
                        encoded.push(encs);
                    }
                    AtomicOp::Update { updates, .. } => {
                        anyhow::ensure!(
                            updates.len() <= u32::MAX as usize,
                            "apply_atomic update leg too large"
                        );
                        payload.extend_from_slice(&(updates.len() as u32).to_le_bytes());
                        let mut encs = Vec::with_capacity(updates.len());
                        for (rid, doc) in updates {
                            anyhow::ensure!(
                                used.insert(*rid),
                                "rid {rid} referenced twice in atomic frame"
                            );
                            c.records
                                .get(rid)
                                .filter(|r| r.dead == LIVE)
                                .ok_or_else(|| anyhow::anyhow!("no record {rid}"))?;
                            let enc = doc.encode();
                            payload.extend_from_slice(&rid.to_le_bytes());
                            payload.extend_from_slice(&(enc.len() as u32).to_le_bytes());
                            payload.extend_from_slice(&enc);
                            encs.push(enc);
                        }
                        encoded.push(encs);
                    }
                    AtomicOp::Remove { rids, .. } => {
                        anyhow::ensure!(
                            rids.len() <= u32::MAX as usize,
                            "apply_atomic remove leg too large"
                        );
                        payload.extend_from_slice(&(rids.len() as u32).to_le_bytes());
                        for &rid in rids {
                            anyhow::ensure!(
                                used.insert(rid),
                                "rid {rid} referenced twice in atomic frame"
                            );
                            c.records
                                .get(&rid)
                                .filter(|r| r.dead == LIVE)
                                .ok_or_else(|| anyhow::anyhow!("no record {rid}"))?;
                            payload.extend_from_slice(&rid.to_le_bytes());
                        }
                        encoded.push(Vec::new());
                    }
                }
            }
        }
        if self.opts.journal {
            self.journal_record(OP_MULTI, ops[0].coll(), &payload);
        }
        // One epoch for the whole frame: a snapshot sees every leg
        // applied or none of them.
        let mut store = write_store(&self.store);
        let epoch = store.epoch + 1;
        let mut fresh = Vec::with_capacity(ops.len());
        for (op, encs) in ops.iter().zip(encoded) {
            // lint: allow(panic, the validation loop above already resolved every collection)
            let c = store
                .collections
                .get_mut(op.coll())
                .expect("collection checked above");
            match op {
                AtomicOp::Insert { docs, .. } => {
                    fresh.push(c.insert_batch(docs, encs, epoch));
                }
                AtomicOp::Update { updates, .. } => {
                    let mut out = Vec::with_capacity(updates.len());
                    for ((rid, doc), enc) in updates.iter().zip(encs) {
                        // lint: allow(panic, every rid was fetched live from this collection above)
                        c.remove(*rid, epoch).expect("record validated above");
                        out.push(c.insert_decoded(doc, enc, epoch));
                    }
                    fresh.push(out);
                }
                AtomicOp::Remove { rids, .. } => {
                    for &rid in rids {
                        // lint: allow(panic, every rid was fetched live from this collection above)
                        c.remove(rid, epoch).expect("record validated above");
                    }
                    fresh.push(Vec::new());
                }
            }
        }
        store.epoch = epoch;
        Ok(fresh)
    }

    /// Remove a record (chunk migration source side).
    pub fn remove(&mut self, coll: &str, rid: RecordId) -> Result<Document> {
        // Validate + decode under a read guard first so a failure never
        // journals, then journal, then apply (single writer: nothing
        // can invalidate the check in between).
        let doc = {
            let store = read_store(&self.store);
            let c = store
                .collections
                .get(coll)
                .ok_or_else(|| anyhow::anyhow!("no collection `{coll}`"))?;
            let rec = c
                .records
                .get(&rid)
                .filter(|r| r.dead == LIVE)
                .ok_or_else(|| anyhow::anyhow!("no record {rid}"))?;
            Document::decode(&rec.bytes)?
        };
        if self.opts.journal {
            let mut payload = rid.to_le_bytes().to_vec();
            payload.extend_from_slice(&doc.encode());
            self.journal_record(OP_REMOVE, coll, &payload);
        }
        let mut store = write_store(&self.store);
        let epoch = store.epoch + 1;
        // lint: allow(panic, validated under the read guard above)
        let c = store.collections.get_mut(coll).expect("collection checked above");
        // lint: allow(panic, the record was fetched live above)
        let doc = c.remove(rid, epoch).expect("record validated above");
        store.epoch = epoch;
        Ok(doc)
    }

    /// Group commit: flush buffered journal records to the open segment,
    /// rotating to a fresh segment once it reaches
    /// [`EngineOptions::segment_bytes`].
    pub fn sync(&mut self) -> Result<()> {
        if !self.opts.journal || self.journal_buf.is_empty() {
            return Ok(());
        }
        if self.journal.is_none() {
            // Segments are created lazily by the first commit they
            // receive, so idle opens and checkpoints never litter empty
            // files (recovery cost stays proportional to written data,
            // not to restart count).
            self.current_seq += 1;
            self.journal = Some(self.dir.create(&segment_name(self.current_seq))?);
        }
        let (seg_len, rotate) = {
            // lint: allow(panic, the branch above replaces None with a fresh segment)
            let j = self.journal.as_mut().expect("journal opened above");
            j.append(&self.journal_buf)?;
            j.sync()?;
            (j.len(), j.len() >= self.opts.segment_bytes())
        };
        self.synced_bytes_since_ckpt += self.journal_buf.len() as u64;
        self.frames_since_ckpt += self.pending_frames;
        self.pending_frames = 0;
        self.journal_buf.clear();
        if rotate {
            self.sealed_bytes += seg_len;
            self.journal = None; // next commit opens segment current_seq+1
        }
        Ok(())
    }

    /// Compact if at least [`EngineOptions::checkpoint_bytes`] of
    /// journal are durable since the last checkpoint — the background
    /// compaction hook the shard server runs after every group commit.
    /// No-op (and `Ok(None)`) below the threshold or when the threshold
    /// is 0 (manual mode).
    pub fn maybe_checkpoint(&mut self) -> Result<Option<CheckpointStats>> {
        if self.opts.checkpoint_bytes == 0
            || self.synced_bytes_since_ckpt < self.opts.checkpoint_bytes
        {
            return Ok(None);
        }
        self.checkpoint().map(Some)
    }

    /// A cloneable read handle for reader threads: snapshots, views,
    /// the open-snapshot gauge. Shares the store and tracker with this
    /// engine.
    pub fn reader(&self) -> StoreReader {
        StoreReader {
            store: Arc::clone(&self.store),
            tracker: Arc::clone(&self.tracker),
        }
    }

    /// Last committed epoch.
    pub fn epoch(&self) -> Epoch {
        read_store(&self.store).epoch
    }

    /// Epoch below which snapshots are expired (reclamation may have
    /// dropped versions they could see).
    pub fn snapshot_floor(&self) -> Epoch {
        read_store(&self.store).floor
    }

    /// Open snapshots across every [`StoreReader`] clone.
    pub fn snapshots_open(&self) -> u64 {
        self.tracker.open_count()
    }

    /// Dead versions still queued for reclamation.
    pub fn garbage_len(&self) -> u64 {
        read_store(&self.store).garbage_len()
    }

    /// Epoch-based reclamation: physically drop every dead version no
    /// open (non-expired) or future snapshot can see. With
    /// [`EngineOptions::snapshot_retention`] set, snapshots pinned more
    /// than that many epochs behind are expired first (their next
    /// [`StoreReader::view`] fails with [`SnapshotExpired`]). Returns
    /// the number of versions reclaimed. The writer calls this after
    /// group commits; it takes the write lock only while popping the
    /// garbage prefix.
    pub fn reclaim(&mut self) -> u64 {
        let mut store = write_store(&self.store);
        let floor = self
            .tracker
            .reclaim_floor(store.epoch, self.opts.snapshot_retention);
        store.reclaim(floor)
    }

    /// Fetch one live record, decoding it. `None` if missing.
    pub fn fetch(&self, coll: &str, rid: RecordId) -> Option<Document> {
        let store = read_store(&self.store);
        store
            .collections
            .get(coll)?
            .records
            .get(&rid)
            .filter(|rec| rec.dead == LIVE)
            // lint: allow(panic, in-memory bytes are validated on every write and replay)
            .map(|rec| Document::decode(&rec.bytes).expect("corrupt record"))
    }

    /// Fetch one live record's *encoded* bytes without decoding,
    /// cloned out of the store. `None` if missing. The zero-copy read
    /// path goes through [`StoreReader::latest`]/[`ReadView::fetch_raw`]
    /// instead, which borrow under the view's guard; this is the
    /// single-threaded convenience.
    pub fn fetch_raw(&self, coll: &str, rid: RecordId) -> Option<Vec<u8>> {
        let store = read_store(&self.store);
        store
            .collections
            .get(coll)?
            .records
            .get(&rid)
            .filter(|rec| rec.dead == LIVE)
            .map(|rec| rec.bytes.clone())
    }

    /// Raw scan in record-id order starting *after* `after` (exclusive;
    /// `None` = from the beginning): encoded bytes only, no per-record
    /// decode. Collects under a read guard and returns owned bytes so
    /// the caller may mutate the engine while iterating; the streaming
    /// shard read path uses [`ReadView::scan_raw_from`] instead.
    pub fn scan_raw_from(
        &self,
        coll: &str,
        after: Option<RecordId>,
    ) -> Box<dyn Iterator<Item = (RecordId, Vec<u8>)>> {
        use std::ops::Bound;
        let lo = match after {
            Some(r) => Bound::Excluded(r),
            None => Bound::Unbounded,
        };
        let store = read_store(&self.store);
        let collected: Vec<(RecordId, Vec<u8>)> = match store.collections.get(coll) {
            Some(c) => c
                .records
                .range((lo, Bound::Unbounded))
                .filter(|(_, rec)| rec.dead == LIVE)
                .map(|(rid, rec)| (*rid, rec.bytes.clone()))
                .collect(),
            None => Vec::new(),
        };
        Box::new(collected.into_iter())
    }

    /// Full scan in record-id order.
    pub fn scan(&self, coll: &str) -> Box<dyn Iterator<Item = (RecordId, Document)>> {
        self.scan_from(coll, None)
    }

    /// Scan in record-id order starting *after* `after` (exclusive;
    /// `None` = from the beginning) — the resumable cursor the chunk
    /// migration stream walks. Records inserted while a stream is
    /// paused get higher ids, so resuming from the last seen id picks
    /// them up. Decoding wrapper over [`Engine::scan_raw_from`].
    pub fn scan_from(
        &self,
        coll: &str,
        after: Option<RecordId>,
    ) -> Box<dyn Iterator<Item = (RecordId, Document)>> {
        Box::new(
            self.scan_raw_from(coll, after)
                // lint: allow(panic, in-memory bytes are validated on every write and replay)
                .map(|(rid, b)| (rid, Document::decode(&b).expect("corrupt record"))),
        )
    }

    /// Live record ids only (migration batching).
    pub fn record_ids(&self, coll: &str) -> Vec<RecordId> {
        let store = read_store(&self.store);
        store
            .collections
            .get(coll)
            .map(|c| {
                c.records
                    .iter()
                    .filter(|(_, rec)| rec.dead == LIVE)
                    .map(|(rid, _)| *rid)
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Next record id `coll` will allocate. Record ids are allocated
    /// serially per collection and never reused, so with no interleaved
    /// write a batch of `n` inserts (or moves into `coll`) lands on
    /// exactly `[next, next + n)` — the shard publish path pre-masks
    /// that run *before* the move commits so no reader can pair a
    /// publish-bearing snapshot with a mask-less fence.
    pub fn next_record_id(&self, coll: &str) -> RecordId {
        read_store(&self.store)
            .collections
            .get(coll)
            .map_or(0, |c| c.next_rid)
    }

    /// Look up a secondary index by name, cloned out of the store (the
    /// read path borrows via [`ReadView::index`] instead).
    pub fn index(&self, coll: &str, name: &str) -> Option<Index> {
        let store = read_store(&self.store);
        store
            .collections
            .get(coll)?
            .indexes
            .iter()
            .find(|i| i.spec.name == name)
            .cloned()
    }

    /// Specs of all secondary indexes on `coll`.
    pub fn indexes(&self, coll: &str) -> Vec<IndexSpec> {
        let store = read_store(&self.store);
        store
            .collections
            .get(coll)
            .map(|c| c.indexes.iter().map(|i| i.spec.clone()).collect())
            .unwrap_or_default()
    }

    /// Live statistics for one collection.
    pub fn stats(&self, coll: &str) -> CollectionStats {
        let store = read_store(&self.store);
        match store.collections.get(coll) {
            Some(c) => CollectionStats {
                docs: c.live,
                bytes: c.bytes,
                index_entries: c.indexes.iter().map(|i| i.entries()).sum(),
            },
            None => CollectionStats::default(),
        }
    }

    /// All collection names, sorted.
    pub fn collection_names(&self) -> Vec<String> {
        let store = read_store(&self.store);
        let mut names: Vec<String> = store.collections.keys().cloned().collect();
        names.sort();
        names
    }

    /// Checkpoint the engine: persist everything in memory, rotate to a
    /// fresh journal segment, and truncate every journal file the
    /// checkpoint covers.
    ///
    /// Most generations write an incremental **delta**
    /// (`delta-NNNNNN.ckpt`) carrying only the records inserted/removed
    /// since the previous generation — cost proportional to work done.
    /// Generation 1, and every generation once the chain holds
    /// [`EngineOptions::full_checkpoint_chain`] deltas, **rebases**: it
    /// writes a full snapshot to `store.ckpt` and deletes the
    /// superseded chain.
    ///
    /// Crash safety: every file stages to `<name>.tmp` and renames — a
    /// kill during a write leaves the previous chain authoritative; a
    /// kill after the swap, during truncation or chain cleanup, is
    /// finished by the next recovery.
    pub fn checkpoint(&mut self) -> Result<CheckpointStats> {
        let rebase = self.generation == 0
            || self.opts.full_checkpoint_chain == 0
            || self.chain_len() >= self.opts.full_checkpoint_chain as u64;
        if rebase {
            self.checkpoint_full()
        } else {
            self.checkpoint_delta()
        }
    }

    /// Write a full snapshot (generation 1 or a chain rebase).
    ///
    /// Body layout: u32 ncolls, then per collection: u8 name_len, name,
    /// u64 next_rid, u32 n_indexes, per index (u8 len, joined field
    /// names), u64 nrecords, then records (u64 rid, u32 len, bytes).
    /// The body is LZSS-compressed when
    /// [`EngineOptions::compress_checkpoints`] is set.
    fn checkpoint_full(&mut self) -> Result<CheckpointStats> {
        // Build the body under a read guard — the snapshot is the live
        // set only (dead-but-retained versions are recreated by nothing:
        // they are invisible to every future snapshot of the reopened
        // store). The file write below happens with no lock held.
        let mut body = Vec::new();
        {
            let store = read_store(&self.store);
            let mut names: Vec<&String> = store.collections.keys().collect();
            names.sort();
            body.extend_from_slice(&(names.len() as u32).to_le_bytes());
            for name in names {
                let c = &store.collections[name];
                body.push(name.len() as u8);
                body.extend_from_slice(name.as_bytes());
                body.extend_from_slice(&c.next_rid.to_le_bytes());
                body.extend_from_slice(&(c.indexes.len() as u32).to_le_bytes());
                for idx in &c.indexes {
                    let joined = idx.spec.fields.join(",");
                    body.push(joined.len() as u8);
                    body.extend_from_slice(joined.as_bytes());
                }
                body.extend_from_slice(&c.live.to_le_bytes());
                for (rid, rec) in c.records.iter().filter(|(_, r)| r.dead == LIVE) {
                    body.extend_from_slice(&rid.to_le_bytes());
                    body.extend_from_slice(&(rec.bytes.len() as u32).to_le_bytes());
                    body.extend_from_slice(&rec.bytes);
                }
            }
        }
        // The snapshot contains every in-memory record, so it covers the
        // open segment (and anything still buffered).
        let generation = self.generation + 1;
        let covered = self.current_seq;
        let mut out = delta::encode_header(&HeaderV3 {
            kind: delta::KIND_FULL,
            generation,
            base_generation: generation,
            covered_seq: covered,
            compressed: self.opts.compress_checkpoints,
        });
        if self.opts.compress_checkpoints {
            out.extend_from_slice(&compress::compress(&body));
        } else {
            out.extend_from_slice(&body);
        }
        let mut stats = CheckpointStats {
            generation,
            checkpoint_bytes: out.len() as u64,
            full: true,
            ..Default::default()
        };
        // Atomic swap: stage + rename. From here the new snapshot is
        // authoritative and any older delta chain is superseded. The
        // in-memory generation advances only on success: a failed write
        // must leave the chain state untouched, or the shard's
        // swallow-and-retry compaction hook would skip a generation.
        self.dir.write_atomic(CKPT, &out)?;
        self.generation = generation;
        self.base_generation = generation;
        self.chain_bytes = 0;
        for name in self.dir.list()? {
            if delta::parse_delta_gen(&name).is_some() {
                let _ = self.dir.remove(&name);
            }
        }
        self.finish_checkpoint(covered, &mut stats)?;
        Ok(stats)
    }

    /// Write an incremental delta over the current chain: only the
    /// records inserted/removed since the previous generation (plus the
    /// per-collection rid allocator and index-spec list, which are
    /// tiny). Cost scales with new writes, not with the live set.
    fn checkpoint_delta(&mut self) -> Result<CheckpointStats> {
        let mut colls;
        {
            let store = read_store(&self.store);
            let mut names: Vec<&String> = store.collections.keys().collect();
            names.sort();
            colls = Vec::with_capacity(names.len());
            for name in names {
                let c = &store.collections[name];
                let mut upserts = Vec::with_capacity(c.dirty.len());
                for rid in &c.dirty {
                    // A dirty rid killed since (born *and* removed within
                    // this interval) nets out of the delta even while its
                    // dead version is retained for open snapshots.
                    if let Some(rec) = c.records.get(rid).filter(|r| r.dead == LIVE) {
                        upserts.push((*rid, rec.bytes.clone()));
                    }
                }
                colls.push(DeltaColl {
                    name: name.clone(),
                    next_rid: c.next_rid,
                    index_specs: c.indexes.iter().map(|i| i.spec.fields.join(",")).collect(),
                    upserts,
                    removes: c.tombstones.iter().copied().collect(),
                });
            }
        }
        let body = delta::encode_body(&colls);
        // Like a full snapshot, the delta persists every in-memory
        // change since the previous generation, so it covers the open
        // segment (and anything still buffered).
        let generation = self.generation + 1;
        let covered = self.current_seq;
        let mut out = delta::encode_header(&HeaderV3 {
            kind: delta::KIND_DELTA,
            generation,
            base_generation: self.base_generation,
            covered_seq: covered,
            compressed: self.opts.compress_checkpoints,
        });
        if self.opts.compress_checkpoints {
            out.extend_from_slice(&compress::compress(&body));
        } else {
            out.extend_from_slice(&body);
        }
        let mut stats = CheckpointStats {
            generation,
            checkpoint_bytes: out.len() as u64,
            delta_bytes: out.len() as u64,
            full: false,
            ..Default::default()
        };
        // Atomic publish: stage + rename, same protocol as the full
        // snapshot. A kill — or a failed write — leaves the chain at the
        // previous generation (at most a `.tmp` recovery discards); the
        // in-memory generation advances only on success, or the shard's
        // swallow-and-retry compaction hook would gap the chain.
        self.dir.write_atomic(&delta::delta_file_name(generation), &out)?;
        self.generation = generation;
        self.chain_bytes += out.len() as u64;
        self.finish_checkpoint(covered, &mut stats)?;
        Ok(stats)
    }

    /// Common checkpoint trailer (full and delta): seal + truncate the
    /// covered journal, reset the compaction trigger and the delta
    /// tracking, and stamp the chain length into `stats`.
    fn finish_checkpoint(&mut self, covered: u64, stats: &mut CheckpointStats) -> Result<()> {
        stats.chain_len = self.chain_len();
        self.journal_buf.clear();
        self.pending_frames = 0;
        if self.opts.journal {
            stats.journal_bytes_truncated =
                self.sealed_bytes + self.journal.as_ref().map(|j| j.len()).unwrap_or(0);
            // Seal the covered journal; the next group commit opens
            // segment covered+1 lazily. A crash before the truncation
            // below finishes leaves only covered segments behind, which
            // recovery skips.
            self.covered_seq = covered;
            self.current_seq = covered;
            self.journal = None;
            if self.dir.exists(JOURNAL_LEGACY) {
                stats.segments_truncated += 1;
                let _ = self.dir.remove(JOURNAL_LEGACY);
            }
            for name in self.dir.list()? {
                if let Some(seq) = parse_segment_seq(&name) {
                    if seq <= covered {
                        stats.segments_truncated += 1;
                        let _ = self.dir.remove(&name);
                    }
                }
            }
        }
        self.sealed_bytes = 0;
        self.synced_bytes_since_ckpt = 0;
        self.frames_since_ckpt = 0;
        // Brief write lock to reset delta tracking; safe against readers
        // (they never look at dirty/tombstones) and there is no other
        // writer to race the published checkpoint.
        let mut store = write_store(&self.store);
        for c in store.collections.values_mut() {
            c.dirty.clear();
            c.tombstones.clear();
        }
        Ok(())
    }

    fn recover(&mut self) -> Result<()> {
        // Recovery is single-threaded — no readers exist yet — so it
        // builds a local `Store` (everything born at epoch 0) and
        // publishes it into the shared lock at the end.
        let mut store = Store::default();
        // A checkpoint staging file (full or delta) can only exist if a
        // crash interrupted the write before its atomic rename; the
        // published chain is authoritative, so discard partials.
        if self.dir.exists(CKPT_TMP) {
            let _ = self.dir.remove(CKPT_TMP);
        }
        for name in self.dir.list()? {
            if name.starts_with("delta-") && name.ends_with(".ckpt.tmp") {
                let _ = self.dir.remove(&name);
            }
        }
        let mut ckpt_version = 0u8;
        if self.dir.exists(CKPT) {
            let raw = self.dir.read(CKPT)?;
            ckpt_version = self
                .load_checkpoint(&mut store, &raw)
                .with_context(|| format!("corrupt checkpoint in {}", self.dir.describe()))?;
        }
        // Whatever store.ckpt held (any header version) is the chain
        // base; fold the delta chain on top of it in generation order.
        self.base_generation = self.generation;
        self.fold_delta_chain(&mut store, ckpt_version)?;
        self.recovery.checkpoint_generation = self.generation;
        // Legacy single-file journal (pre-segment layout). A v2+
        // checkpoint — or any delta — is only ever written by an engine
        // version that had already replayed (or written) the legacy
        // journal into memory, so when one exists the legacy file is
        // covered: the kill landed between the checkpoint swap and the
        // legacy removal, and replaying it would double-apply every
        // document. Otherwise (no checkpoint, or a v1 one that
        // truncated the file in place) whatever is on disk is the tail:
        // replay it.
        if self.dir.exists(JOURNAL_LEGACY) {
            if ckpt_version >= 2 || self.recovery.deltas_folded > 0 {
                self.recovery.segments_skipped += 1;
                let _ = self.dir.remove(JOURNAL_LEGACY);
            } else {
                let raw = self.dir.read(JOURNAL_LEGACY)?;
                self.replay_journal(&mut store, &raw)
                    .with_context(|| format!("corrupt journal in {}", self.dir.describe()))?;
                self.sealed_bytes += raw.len() as u64;
                self.recovery.segments_replayed += 1;
            }
        }
        // Segmented journal: replay post-checkpoint segments in order.
        // Covered segments are already in the checkpoint — delete them,
        // finishing any truncation a crash interrupted.
        let mut seqs: Vec<u64> = self
            .dir
            .list()?
            .iter()
            .filter_map(|n| parse_segment_seq(n))
            .collect();
        seqs.sort_unstable();
        for seq in seqs {
            self.current_seq = self.current_seq.max(seq);
            if seq <= self.covered_seq {
                self.recovery.segments_skipped += 1;
                let _ = self.dir.remove(&segment_name(seq));
                continue;
            }
            let raw = self.dir.read(&segment_name(seq))?;
            self.replay_journal(&mut store, &raw).with_context(|| {
                format!("corrupt journal segment {seq} in {}", self.dir.describe())
            })?;
            self.sealed_bytes += raw.len() as u64;
            self.recovery.segments_replayed += 1;
        }
        self.current_seq = self.current_seq.max(self.covered_seq);
        // The replayed tail is durable-but-uncheckpointed work: seed the
        // compaction trigger with it, or repeated kill-restart cycles
        // that each stay below the threshold would grow the journal (and
        // the next replay) without bound.
        self.synced_bytes_since_ckpt = self.recovery.bytes_replayed;
        self.frames_since_ckpt = self.recovery.frames_replayed;
        // Replayed removes left born-and-dead-at-0 versions (invisible
        // to everyone); no snapshot is open, so drop them before
        // publishing the store.
        store.reclaim(store.epoch);
        store.floor = 0;
        *write_store(&self.store) = store;
        Ok(())
    }

    /// Load the base checkpoint (`store.ckpt`), returning its header
    /// version (1 = legacy `HPCCKPT1`, 2 = legacy `HPCCKPT2`, 3 =
    /// `HPCCKPT3` full snapshot). Legacy stores upgrade in place: the
    /// first delta written on top of a v1/v2 base simply chains on its
    /// generation.
    fn load_checkpoint(&mut self, store: &mut Store, raw: &[u8]) -> Result<u8> {
        if raw.len() >= 9 && &raw[..8] == CKPT_MAGIC_V1 {
            // Legacy header: no generation or segment watermark.
            self.generation = 1;
            self.covered_seq = 0;
            self.load_checkpoint_body(store, raw[8], &raw[9..])?;
            return Ok(1);
        }
        if raw.len() >= 25 && &raw[..8] == CKPT_MAGIC_V2 {
            self.generation = u64::from_le_bytes(raw[8..16].try_into()?);
            self.covered_seq = u64::from_le_bytes(raw[16..24].try_into()?);
            self.load_checkpoint_body(store, raw[24], &raw[25..])?;
            return Ok(2);
        }
        if raw.len() >= delta::HEADER_LEN && &raw[..8] == delta::MAGIC_V3 {
            let (hdr, payload) = delta::parse_header(raw)?;
            if hdr.kind != delta::KIND_FULL {
                bail!("store.ckpt is not a full snapshot");
            }
            self.generation = hdr.generation;
            self.covered_seq = hdr.covered_seq;
            self.load_checkpoint_body(store, hdr.compressed as u8, payload)?;
            return Ok(3);
        }
        bail!("bad checkpoint magic");
    }

    /// Fold the on-disk delta chain onto the loaded base snapshot, in
    /// generation order. Deltas that do not extend the current base —
    /// an older chain a crashed rebase did not finish deleting, or
    /// orphans with no base at all — are already contained in the base
    /// snapshot, so they are deleted, never folded (folding one would
    /// double-apply). A same-base gap is real corruption and fails
    /// recovery.
    fn fold_delta_chain(&mut self, store: &mut Store, ckpt_version: u8) -> Result<()> {
        let mut chain: Vec<(u64, String)> = self
            .dir
            .list()?
            .into_iter()
            .filter_map(|n| delta::parse_delta_gen(&n).map(|g| (g, n)))
            .collect();
        chain.sort_unstable();
        for (gen, name) in chain {
            if ckpt_version == 0 || gen <= self.generation {
                // Orphan (no base on disk) or superseded by a newer full
                // snapshot: finish the interrupted cleanup.
                let _ = self.dir.remove(&name);
                continue;
            }
            let raw = self.dir.read(&name)?;
            let (hdr, payload) = delta::parse_header(&raw).with_context(|| {
                format!("corrupt delta checkpoint {name} in {}", self.dir.describe())
            })?;
            if hdr.kind != delta::KIND_DELTA || hdr.base_generation != self.base_generation {
                // A chain built on a superseded base: the current full
                // snapshot already contains its effect.
                let _ = self.dir.remove(&name);
                continue;
            }
            if hdr.generation != gen || hdr.generation != self.generation + 1 {
                bail!(
                    "broken delta chain in {}: {name} has generation {} over base {}, expected {}",
                    self.dir.describe(),
                    hdr.generation,
                    hdr.base_generation,
                    self.generation + 1
                );
            }
            let body = if hdr.compressed {
                compress::decompress(payload)?
            } else {
                payload.to_vec()
            };
            let colls = delta::decode_body(&body).with_context(|| {
                format!("corrupt delta checkpoint {name} in {}", self.dir.describe())
            })?;
            self.fold_delta(store, colls)?;
            self.generation = hdr.generation;
            self.covered_seq = self.covered_seq.max(hdr.covered_seq);
            self.chain_bytes += raw.len() as u64;
            self.recovery.deltas_folded += 1;
            self.recovery.delta_bytes_folded += raw.len() as u64;
        }
        Ok(())
    }

    /// Apply one decoded delta to the in-memory state (recovery fold).
    fn fold_delta(&mut self, store: &mut Store, colls: Vec<DeltaColl>) -> Result<()> {
        for dc in colls {
            create_collection_in(store, &dc.name);
            // Index specs new to the fold backfill from the records
            // folded so far; already-known specs are untouched
            // (`create_index_in` is idempotent).
            for joined in &dc.index_specs {
                let fields: Vec<&str> = joined.split(',').collect();
                create_index_in(store, &dc.name, IndexSpec::compound(&fields))?;
            }
            // lint: allow(panic, create_collection_in in the loop above inserts the entry)
            let c = store.collections.get_mut(&dc.name).expect("collection created above");
            for (rid, bytes) in dc.upserts {
                c.apply_upsert(rid, bytes)?;
            }
            for rid in dc.removes {
                c.apply_remove(rid);
            }
            c.next_rid = c.next_rid.max(dc.next_rid);
        }
        Ok(())
    }

    fn load_checkpoint_body(
        &mut self,
        store: &mut Store,
        compressed: u8,
        payload: &[u8],
    ) -> Result<()> {
        let body: Vec<u8> = if compressed == 1 {
            compress::decompress(payload)?
        } else {
            payload.to_vec()
        };
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > body.len() {
                bail!("truncated checkpoint");
            }
            let s = &body[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let ncolls = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        for _ in 0..ncolls {
            let name_len = take(&mut pos, 1)?[0] as usize;
            let name = std::str::from_utf8(take(&mut pos, name_len)?)?.to_string();
            let next_rid = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?);
            let n_idx = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
            let mut specs = Vec::new();
            for _ in 0..n_idx {
                let len = take(&mut pos, 1)?[0] as usize;
                let joined = std::str::from_utf8(take(&mut pos, len)?)?;
                let fields: Vec<&str> = joined.split(',').collect();
                specs.push(IndexSpec::compound(&fields));
            }
            let nrec = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?);
            let mut c = Collection::new();
            for spec in specs {
                c.indexes.push(Index::new(spec));
            }
            for _ in 0..nrec {
                let rid = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?);
                let len = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
                let bytes = take(&mut pos, len)?.to_vec();
                let doc = Document::decode(&bytes)?;
                c.bytes += bytes.len() as u64;
                c.live += 1;
                c.records.insert(rid, VRecord { born: 0, dead: LIVE, bytes });
                for idx in &mut c.indexes {
                    idx.insert(&doc, rid);
                }
            }
            c.next_rid = next_rid;
            store.collections.insert(name, c);
        }
        Ok(())
    }

    fn replay_journal(&mut self, store: &mut Store, raw: &[u8]) -> Result<()> {
        let mut pos = 0usize;
        while pos + 4 <= raw.len() {
            let len = u32::from_le_bytes(raw[pos..pos + 4].try_into()?) as usize;
            pos += 4;
            if pos + len > raw.len() {
                // Torn tail write — stop at the last complete frame. A
                // half-written insert_many frame is dropped whole here,
                // so a mid-batch crash never half-applies a batch.
                eprintln!("warn: journal tail truncated at byte {pos}; dropping partial record");
                break;
            }
            let rec = &raw[pos..pos + len];
            pos += len;
            if rec.len() < 2 {
                bail!("journal record shorter than its header");
            }
            let op = rec[0];
            let coll_len = rec[1] as usize;
            if 2 + coll_len > rec.len() {
                bail!("journal record collection name overruns frame");
            }
            let coll = std::str::from_utf8(&rec[2..2 + coll_len])?.to_string();
            let payload = &rec[2 + coll_len..];
            create_collection_in(store, &coll);
            // lint: allow(panic, create_collection_in on the line above inserts the entry)
            let c = store.collections.get_mut(&coll).unwrap();
            match op {
                OP_INSERT => {
                    let doc = Document::decode(payload)?;
                    c.insert_decoded(&doc, payload.to_vec(), 0);
                }
                OP_REMOVE => {
                    if payload.len() < 8 {
                        bail!("remove record shorter than its rid");
                    }
                    let rid = u64::from_le_bytes(payload[..8].try_into()?);
                    let _ = c.remove(rid, 0);
                }
                OP_INSERT_MANY => {
                    if payload.len() < 4 {
                        bail!("insert_many frame missing count");
                    }
                    let ndocs = u32::from_le_bytes(payload[..4].try_into()?) as usize;
                    let mut p = 4usize;
                    for i in 0..ndocs {
                        if p + 4 > payload.len() {
                            bail!("insert_many frame truncated at doc {i} length");
                        }
                        let dl = u32::from_le_bytes(payload[p..p + 4].try_into()?) as usize;
                        p += 4;
                        if p + dl > payload.len() {
                            bail!("insert_many frame truncated at doc {i} body");
                        }
                        let bytes = payload[p..p + dl].to_vec();
                        p += dl;
                        let doc = Document::decode(&bytes)?;
                        c.insert_decoded(&doc, bytes, 0);
                    }
                    if p != payload.len() {
                        bail!("insert_many frame has trailing bytes");
                    }
                }
                OP_REMOVE_MANY => {
                    if payload.len() < 4 {
                        bail!("remove_many frame missing count");
                    }
                    let n = u32::from_le_bytes(payload[..4].try_into()?) as usize;
                    let mut p = 4usize;
                    for i in 0..n {
                        if p + 8 > payload.len() {
                            bail!("remove_many frame truncated at record {i}");
                        }
                        let rid = u64::from_le_bytes(payload[p..p + 8].try_into()?);
                        p += 8;
                        let _ = c.remove(rid, 0);
                    }
                    if p != payload.len() {
                        bail!("remove_many frame has trailing bytes");
                    }
                }
                OP_MOVE_MANY => {
                    if payload.is_empty() {
                        bail!("move_many frame missing destination");
                    }
                    let dst_len = payload[0] as usize;
                    if 1 + dst_len + 4 > payload.len() {
                        bail!("move_many frame truncated at destination name");
                    }
                    let dst = std::str::from_utf8(&payload[1..1 + dst_len])?.to_string();
                    let n = u32::from_le_bytes(
                        payload[1 + dst_len..1 + dst_len + 4].try_into()?,
                    ) as usize;
                    let mut p = 1 + dst_len + 4;
                    let mut recs: Vec<(RecordId, Vec<u8>)> = Vec::with_capacity(n);
                    for i in 0..n {
                        if p + 12 > payload.len() {
                            bail!("move_many frame truncated at record {i}");
                        }
                        let rid = u64::from_le_bytes(payload[p..p + 8].try_into()?);
                        p += 8;
                        let dl = u32::from_le_bytes(payload[p..p + 4].try_into()?) as usize;
                        p += 4;
                        if p + dl > payload.len() {
                            bail!("move_many frame truncated at record {i} body");
                        }
                        recs.push((rid, payload[p..p + dl].to_vec()));
                        p += dl;
                    }
                    if p != payload.len() {
                        bail!("move_many frame has trailing bytes");
                    }
                    // Same order as the live path: remove from the frame's
                    // source collection (the header name), then install
                    // into the destination with freshly allocated rids —
                    // replay reproduces the live allocation exactly.
                    create_collection_in(store, &dst);
                    // lint: allow(panic, create_collection_in(&coll) ran before this match)
                    let src_c = store.collections.get_mut(&coll).expect("created above");
                    let mut docs = Vec::with_capacity(recs.len());
                    let mut encs = Vec::with_capacity(recs.len());
                    for (rid, bytes) in recs {
                        let _ = src_c.remove(rid, 0);
                        docs.push(Document::decode(&bytes)?);
                        encs.push(bytes);
                    }
                    // lint: allow(panic, create_collection_in(&dst) at the top of this arm)
                    let dst_c = store.collections.get_mut(&dst).expect("created above");
                    dst_c.insert_batch(&docs, encs, 0);
                }
                OP_UPDATE_MANY => {
                    if payload.len() < 4 {
                        bail!("update_many frame missing count");
                    }
                    let n = u32::from_le_bytes(payload[..4].try_into()?) as usize;
                    let mut p = 4usize;
                    let mut recs: Vec<(RecordId, Vec<u8>)> = Vec::with_capacity(n);
                    for i in 0..n {
                        if p + 12 > payload.len() {
                            bail!("update_many frame truncated at record {i}");
                        }
                        let rid = u64::from_le_bytes(payload[p..p + 8].try_into()?);
                        p += 8;
                        let dl = u32::from_le_bytes(payload[p..p + 4].try_into()?) as usize;
                        p += 4;
                        if p + dl > payload.len() {
                            bail!("update_many frame truncated at record {i} body");
                        }
                        recs.push((rid, payload[p..p + dl].to_vec()));
                        p += dl;
                    }
                    if p != payload.len() {
                        bail!("update_many frame has trailing bytes");
                    }
                    // Same order as the live path: kill the old version,
                    // then install the replacement under a freshly
                    // allocated rid — replay reproduces the live
                    // allocation exactly.
                    for (rid, bytes) in recs {
                        let _ = c.remove(rid, 0);
                        let doc = Document::decode(&bytes)?;
                        c.insert_decoded(&doc, bytes, 0);
                    }
                }
                OP_DELETE_MANY => {
                    if payload.len() < 4 {
                        bail!("delete_many frame missing count");
                    }
                    let n = u32::from_le_bytes(payload[..4].try_into()?) as usize;
                    let mut p = 4usize;
                    for i in 0..n {
                        if p + 8 > payload.len() {
                            bail!("delete_many frame truncated at record {i}");
                        }
                        let rid = u64::from_le_bytes(payload[p..p + 8].try_into()?);
                        p += 8;
                        let _ = c.remove(rid, 0);
                    }
                    if p != payload.len() {
                        bail!("delete_many frame has trailing bytes");
                    }
                }
                OP_MULTI => {
                    if payload.len() < 4 {
                        bail!("multi frame missing op count");
                    }
                    let nops = u32::from_le_bytes(payload[..4].try_into()?) as usize;
                    let mut p = 4usize;
                    for i in 0..nops {
                        if p + 2 > payload.len() {
                            bail!("multi frame truncated at leg {i} header");
                        }
                        let kind = payload[p];
                        let clen = payload[p + 1] as usize;
                        p += 2;
                        if p + clen + 4 > payload.len() {
                            bail!("multi frame truncated at leg {i} collection");
                        }
                        let oc = std::str::from_utf8(&payload[p..p + clen])?.to_string();
                        p += clen;
                        let n = u32::from_le_bytes(payload[p..p + 4].try_into()?) as usize;
                        p += 4;
                        create_collection_in(store, &oc);
                        // lint: allow(panic, create_collection_in on the line above inserts the entry)
                        let lc = store.collections.get_mut(&oc).unwrap();
                        match kind {
                            0 => {
                                for j in 0..n {
                                    if p + 4 > payload.len() {
                                        bail!("multi frame truncated at leg {i} doc {j}");
                                    }
                                    let dl = u32::from_le_bytes(payload[p..p + 4].try_into()?)
                                        as usize;
                                    p += 4;
                                    if p + dl > payload.len() {
                                        bail!("multi frame truncated at leg {i} doc {j} body");
                                    }
                                    let bytes = payload[p..p + dl].to_vec();
                                    p += dl;
                                    let doc = Document::decode(&bytes)?;
                                    lc.insert_decoded(&doc, bytes, 0);
                                }
                            }
                            1 => {
                                // Same order as the live path: kill the
                                // old version, install the replacement
                                // under a freshly allocated rid.
                                for j in 0..n {
                                    if p + 12 > payload.len() {
                                        bail!("multi frame truncated at leg {i} update {j}");
                                    }
                                    let rid =
                                        u64::from_le_bytes(payload[p..p + 8].try_into()?);
                                    p += 8;
                                    let dl = u32::from_le_bytes(payload[p..p + 4].try_into()?)
                                        as usize;
                                    p += 4;
                                    if p + dl > payload.len() {
                                        bail!("multi frame truncated at leg {i} update {j} body");
                                    }
                                    let bytes = payload[p..p + dl].to_vec();
                                    p += dl;
                                    let _ = lc.remove(rid, 0);
                                    let doc = Document::decode(&bytes)?;
                                    lc.insert_decoded(&doc, bytes, 0);
                                }
                            }
                            2 => {
                                for j in 0..n {
                                    if p + 8 > payload.len() {
                                        bail!("multi frame truncated at leg {i} remove {j}");
                                    }
                                    let rid =
                                        u64::from_le_bytes(payload[p..p + 8].try_into()?);
                                    p += 8;
                                    let _ = lc.remove(rid, 0);
                                }
                            }
                            k => bail!("unknown multi-frame leg kind {k}"),
                        }
                    }
                    if p != payload.len() {
                        bail!("multi frame has trailing bytes");
                    }
                }
                _ => bail!("unknown journal op {op}"),
            }
            self.recovery.frames_replayed += 1;
            self.recovery.bytes_replayed += 4 + len as u64;
        }
        Ok(())
    }

    fn journal_record(&mut self, op: u8, coll: &str, payload: &[u8]) {
        let len = 2 + coll.len() + payload.len();
        self.journal_buf.extend_from_slice(&(len as u32).to_le_bytes());
        self.journal_buf.push(op);
        self.journal_buf.push(coll.len() as u8);
        self.journal_buf.extend_from_slice(coll.as_bytes());
        self.journal_buf.extend_from_slice(payload);
        self.pending_frames += 1;
    }

    /// Bytes of journal waiting for the next group commit (tests/metrics).
    pub fn pending_journal_bytes(&self) -> usize {
        self.journal_buf.len()
    }

    /// Durable journal bytes accumulated since the last checkpoint —
    /// the auto-compaction trigger variable.
    pub fn journal_bytes_since_checkpoint(&self) -> u64 {
        self.synced_bytes_since_ckpt
    }

    /// Durable journal frames accumulated since the last checkpoint.
    pub fn frames_since_checkpoint(&self) -> u64 {
        self.frames_since_ckpt
    }

    /// Total on-disk journal footprint: live sealed segments plus the
    /// open segment. This is the quantity the lifecycle bounds.
    pub fn journal_disk_bytes(&self) -> u64 {
        self.sealed_bytes + self.journal.as_ref().map(|j| j.len()).unwrap_or(0)
    }

    /// Generation of the newest checkpoint, full or delta (0 = never
    /// checkpointed).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Generation of the on-disk full snapshot the delta chain builds
    /// on (0 = never checkpointed).
    pub fn base_generation(&self) -> u64 {
        self.base_generation
    }

    /// Delta generations on top of the on-disk full snapshot (0 right
    /// after a rebase — recovery folds exactly this many deltas).
    pub fn chain_len(&self) -> u64 {
        self.generation - self.base_generation
    }

    /// On-disk bytes of the live delta chain (the checkpoint-side
    /// footprint the rebase threshold bounds).
    pub fn chain_disk_bytes(&self) -> u64 {
        self.chain_bytes
    }

    /// What the opening recovery replayed.
    pub fn recovery_report(&self) -> &RecoveryReport {
        &self.recovery
    }

    /// The lifecycle options this engine runs with.
    pub fn options(&self) -> &EngineOptions {
        &self.opts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mongo::bson::Value;
    use crate::mongo::storage::io::LocalDir;

    fn doc(ts: i64, node: i64) -> Document {
        Document::new().set("ts", ts).set("node_id", node).set("m0", ts as f64 * 0.5)
    }

    fn temp_engine(label: &str, journal: bool, compress: bool) -> (Engine, String) {
        let dir = LocalDir::temp(label).unwrap();
        let path = dir.describe();
        let eng = Engine::open(Box::new(dir), journal, compress).unwrap();
        (eng, path)
    }

    /// The first segment an engine on a fresh directory writes to.
    const SEG1: &str = "journal-000001.wal";

    #[test]
    fn insert_fetch_scan() {
        let (mut eng, _) = temp_engine("eng1", true, false);
        eng.create_collection("metrics");
        let r0 = eng.insert("metrics", &doc(1, 10)).unwrap();
        let r1 = eng.insert("metrics", &doc(2, 20)).unwrap();
        assert_ne!(r0, r1);
        assert_eq!(eng.fetch("metrics", r0).unwrap().get_i64("node_id"), Some(10));
        assert_eq!(eng.scan("metrics").count(), 2);
        let s = eng.stats("metrics");
        assert_eq!(s.docs, 2);
        assert!(s.bytes > 0);
    }

    #[test]
    fn raw_fetch_and_scan_expose_encoded_bytes() {
        use crate::mongo::bson::RawDoc;
        let (mut eng, _) = temp_engine("eng1raw", false, false);
        eng.create_collection("m");
        let r0 = eng.insert("m", &doc(7, 70)).unwrap();
        eng.insert("m", &doc(8, 80)).unwrap();
        let raw = eng.fetch_raw("m", r0).unwrap();
        assert_eq!(raw, doc(7, 70).encode());
        assert_eq!(RawDoc::new(&raw).get_i64("node_id"), Some(70));
        assert!(eng.fetch_raw("m", 999).is_none());
        // Raw scan agrees with the decoding scan, resumes after a rid.
        let all: Vec<RecordId> = eng.scan_raw_from("m", None).map(|(r, _)| r).collect();
        assert_eq!(all, eng.record_ids("m"));
        let tail: Vec<RecordId> = eng.scan_raw_from("m", Some(r0)).map(|(r, _)| r).collect();
        assert_eq!(tail, vec![r0 + 1]);
        assert_eq!(eng.scan_raw_from("nope", None).count(), 0);
    }

    #[test]
    fn indexes_maintained_on_insert_and_remove() {
        let (mut eng, _) = temp_engine("eng2", false, false);
        eng.create_collection("metrics");
        eng.create_index("metrics", IndexSpec::single("node_id")).unwrap();
        let r0 = eng.insert("metrics", &doc(1, 7)).unwrap();
        eng.insert("metrics", &doc(2, 7)).unwrap();
        let idx = eng.index("metrics", "node_id_1").unwrap();
        assert_eq!(idx.point(&[&Value::Int(7)]).len(), 2);
        eng.remove("metrics", r0).unwrap();
        let idx = eng.index("metrics", "node_id_1").unwrap();
        assert_eq!(idx.point(&[&Value::Int(7)]).len(), 1);
    }

    #[test]
    fn index_backfills_existing_records() {
        let (mut eng, _) = temp_engine("eng3", false, false);
        eng.create_collection("metrics");
        for t in 0..20 {
            eng.insert("metrics", &doc(t, t % 4)).unwrap();
        }
        eng.create_index("metrics", IndexSpec::single("ts")).unwrap();
        let idx = eng.index("metrics", "ts_1").unwrap();
        assert_eq!(idx.range(Some(&Value::Int(5)), Some(&Value::Int(15))).count(), 10);
    }

    #[test]
    fn journal_recovery_after_crash() {
        let dir = LocalDir::temp("eng4").unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("metrics");
            for t in 0..10 {
                eng.insert("metrics", &doc(t, 1)).unwrap();
            }
            eng.sync().unwrap();
            // Drop without checkpoint = crash.
        }
        let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("metrics").docs, 10);
        assert_eq!(eng.fetch("metrics", 3).unwrap().get_i64("ts"), Some(3));
        assert_eq!(eng.recovery_report().frames_replayed, 10);
    }

    #[test]
    fn unsynced_writes_are_lost_on_crash() {
        let dir = LocalDir::temp("eng5").unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("metrics");
            eng.insert("metrics", &doc(1, 1)).unwrap();
            eng.sync().unwrap();
            eng.insert("metrics", &doc(2, 2)).unwrap();
            // no sync — buffered record lost
            assert!(eng.pending_journal_bytes() > 0);
        }
        let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("metrics").docs, 1);
    }

    #[test]
    fn checkpoint_then_recover_without_journal_replay() {
        for compress in [false, true] {
            let dir = LocalDir::temp("eng6").unwrap();
            let root = dir.describe();
            {
                let mut eng = Engine::open(Box::new(dir), true, compress).unwrap();
                eng.create_collection("metrics");
                eng.create_index("metrics", IndexSpec::single("node_id")).unwrap();
                for t in 0..25 {
                    eng.insert("metrics", &doc(t, t % 3)).unwrap();
                }
                eng.sync().unwrap();
                let ck = eng.checkpoint().unwrap();
                assert_eq!(ck.generation, 1);
                assert!(ck.segments_truncated >= 1, "covered segment must go");
                // Post-checkpoint writes land in the fresh journal.
                eng.insert("metrics", &doc(100, 9)).unwrap();
                eng.sync().unwrap();
            }
            let eng =
                Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, compress).unwrap();
            assert_eq!(eng.stats("metrics").docs, 26, "compress={compress}");
            // Only the post-checkpoint tail replays.
            assert_eq!(eng.recovery_report().checkpoint_generation, 1);
            assert_eq!(eng.recovery_report().frames_replayed, 1);
            // Indexes rebuilt from checkpoint specs + journal replay.
            let idx = eng.index("metrics", "node_id_1").unwrap();
            assert_eq!(idx.point(&[&Value::Int(9)]).len(), 1);
        }
    }

    #[test]
    fn remove_journaled_and_replayed() {
        let dir = LocalDir::temp("eng7").unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("m");
            let r = eng.insert("m", &doc(1, 1)).unwrap();
            eng.insert("m", &doc(2, 2)).unwrap();
            eng.remove("m", r).unwrap();
            eng.sync().unwrap();
        }
        let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("m").docs, 1);
        assert!(eng.fetch("m", 0).is_none());
    }

    #[test]
    fn torn_journal_tail_is_tolerated() {
        let dir = LocalDir::temp("eng8").unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("m");
            eng.insert("m", &doc(1, 1)).unwrap();
            eng.sync().unwrap();
        }
        // Append a torn record: length prefix promising more bytes.
        {
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new()
                .append(true)
                .open(std::path::Path::new(&root).join(SEG1))
                .unwrap();
            f.write_all(&100u32.to_le_bytes()).unwrap();
            f.write_all(&[1, 1, b'm']).unwrap(); // incomplete
        }
        let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("m").docs, 1);
    }

    #[test]
    fn insert_many_is_one_frame_and_recovers() {
        let dir = LocalDir::temp("eng10").unwrap();
        let root = dir.describe();
        let docs: Vec<Document> = (0..10).map(|t| doc(t, t % 3)).collect();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("m");
            eng.create_index("m", IndexSpec::single("node_id")).unwrap();
            let rids = eng.insert_many("m", &docs).unwrap();
            assert_eq!(rids.len(), 10);
            assert_eq!(eng.stats("m").docs, 10);

            // Batched framing must be strictly cheaper than ten
            // individual insert frames.
            let (mut single, _) = temp_engine("eng10b", true, false);
            single.create_collection("m");
            for d in &docs {
                single.insert("m", d).unwrap();
            }
            assert!(
                eng.pending_journal_bytes() < single.pending_journal_bytes(),
                "batch frame {} >= individual frames {}",
                eng.pending_journal_bytes(),
                single.pending_journal_bytes()
            );
            eng.sync().unwrap();
            // Drop without checkpoint = crash after group commit.
        }
        let mut eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("m").docs, 10);
        assert_eq!(eng.fetch("m", 7).unwrap().get_i64("ts"), Some(7));
        // Index specs are not journaled (only checkpointed); rebuild and
        // verify entries, then check rid allocation continues past the
        // replayed batch.
        eng.create_index("m", IndexSpec::single("node_id")).unwrap();
        let idx = eng.index("m", "node_id_1").unwrap();
        assert_eq!(idx.point(&[&Value::Int(0)]).len(), 4); // nodes 0,3,6,9
        let rid = eng.insert("m", &doc(99, 9)).unwrap();
        assert_eq!(rid, 10);
    }

    #[test]
    fn unsynced_batch_is_lost_whole_on_crash() {
        let dir = LocalDir::temp("eng12").unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("m");
            eng.insert_many("m", &[doc(1, 1)]).unwrap();
            eng.sync().unwrap();
            eng.insert_many("m", &(0..4).map(|t| doc(10 + t, 2)).collect::<Vec<_>>())
                .unwrap();
            // No sync: the whole second batch is buffered only.
            assert!(eng.pending_journal_bytes() > 0);
        }
        let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("m").docs, 1);
    }

    #[test]
    fn torn_batched_frame_is_discarded_whole() {
        // Build a real batched journal frame in a scratch engine.
        let scratch = LocalDir::temp("eng13-frame").unwrap();
        let scratch_root = scratch.describe();
        {
            let mut eng = Engine::open(Box::new(scratch), true, false).unwrap();
            eng.create_collection("m");
            let batch: Vec<Document> = (100..103).map(|t| doc(t, 1)).collect();
            eng.insert_many("m", &batch).unwrap();
            eng.sync().unwrap();
        }
        let frame =
            std::fs::read(std::path::Path::new(&scratch_root).join(SEG1)).unwrap();

        // Base journal: one synced batch of 5 documents.
        let base_dir = LocalDir::temp("eng13-base").unwrap();
        let base_root = base_dir.describe();
        {
            let mut eng = Engine::open(Box::new(base_dir), true, false).unwrap();
            eng.create_collection("m");
            eng.insert_many("m", &(0..5).map(|t| doc(t, 0)).collect::<Vec<_>>())
                .unwrap();
            eng.sync().unwrap();
        }
        let base = std::fs::read(std::path::Path::new(&base_root).join(SEG1)).unwrap();

        // Scenario A — the second batch's frame was fully written before
        // the crash: it replays atomically (5 + 3 docs).
        {
            let dir = LocalDir::temp("eng13-a").unwrap();
            let root = dir.describe();
            let mut bytes = base.clone();
            bytes.extend_from_slice(&frame);
            std::fs::write(std::path::Path::new(&root).join(SEG1), &bytes).unwrap();
            let eng =
                Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
            assert_eq!(eng.stats("m").docs, 8);
            assert_eq!(eng.fetch("m", 5).unwrap().get_i64("ts"), Some(100));
        }

        // Scenario B — killed mid-batch: only a prefix of the frame hit
        // the journal. The torn frame must be dropped in full; none of
        // its documents may replay.
        for cut in [1usize, 7, frame.len() - 1] {
            let dir = LocalDir::temp(&format!("eng13-b{cut}")).unwrap();
            let root = dir.describe();
            let mut bytes = base.clone();
            bytes.extend_from_slice(&frame[..cut]);
            std::fs::write(std::path::Path::new(&root).join(SEG1), &bytes).unwrap();
            let eng =
                Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
            assert_eq!(eng.stats("m").docs, 5, "cut={cut}: torn batch must not replay");
        }
    }

    #[test]
    fn remove_decode_failure_leaves_collection_consistent() {
        let mut c = Collection::new();
        // Not a decodable document.
        c.records.insert(0, VRecord { born: 0, dead: LIVE, bytes: vec![0xFF, 0xEE] });
        c.bytes = 2;
        c.live = 1;
        assert!(c.remove(0, 1).is_err());
        assert_eq!((c.bytes, c.live), (2, 1), "accounting must be untouched");
        let rec = c.records.get(&0).expect("record must not be stranded");
        assert_eq!(rec.dead, LIVE, "failed remove must not stamp the version dead");
        assert!(c.garbage.is_empty());
    }

    #[test]
    fn journaling_disabled_skips_wal() {
        let (mut eng, root) = temp_engine("eng9", false, false);
        eng.create_collection("m");
        eng.insert("m", &doc(1, 1)).unwrap();
        eng.sync().unwrap();
        assert_eq!(eng.pending_journal_bytes(), 0);
        assert!(!std::path::Path::new(&root).join(SEG1).exists());
        assert!(!std::path::Path::new(&root).join("journal.wal").exists());
    }

    #[test]
    fn segments_rotate_and_all_replay() {
        // Small derived segment size (2 KiB) without auto-compaction:
        // maybe_checkpoint is simply never called.
        let opts = EngineOptions {
            journal: true,
            compress_checkpoints: false,
            checkpoint_bytes: 8192,
            journal_segments: 4,
            ..EngineOptions::default()
        };
        let dir = LocalDir::temp("eng14").unwrap();
        let root = dir.describe();
        let mut total = 0u64;
        {
            let mut eng = Engine::open_with(Box::new(dir), opts.clone()).unwrap();
            eng.create_collection("m");
            for b in 0..12 {
                let batch: Vec<Document> =
                    (0..20).map(|i| doc(b * 20 + i, (b * 20 + i) % 5)).collect();
                total += batch.len() as u64;
                eng.insert_many("m", &batch).unwrap();
                eng.sync().unwrap();
            }
            let segs = std::fs::read_dir(&root)
                .unwrap()
                .filter(|e| {
                    parse_segment_seq(
                        &e.as_ref().unwrap().file_name().to_string_lossy(),
                    )
                    .is_some()
                })
                .count();
            assert!(segs >= 2, "expected rotation, got {segs} segment(s)");
        }
        let eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
        assert_eq!(eng.stats("m").docs, total);
        assert!(eng.recovery_report().segments_replayed >= 2);
    }

    #[test]
    fn maybe_checkpoint_bounds_journal_and_recovers() {
        let opts = EngineOptions {
            journal: true,
            compress_checkpoints: true,
            checkpoint_bytes: 16 * 1024,
            journal_segments: 4,
            ..EngineOptions::default()
        };
        let dir = LocalDir::temp("eng15").unwrap();
        let root = dir.describe();
        let mut total = 0u64;
        {
            let mut eng = Engine::open_with(Box::new(dir), opts.clone()).unwrap();
            eng.create_collection("m");
            let mut compactions = 0u64;
            for b in 0..80 {
                let batch: Vec<Document> =
                    (0..16).map(|i| doc(b * 16 + i, (b * 16 + i) % 5)).collect();
                total += batch.len() as u64;
                eng.insert_many("m", &batch).unwrap();
                eng.sync().unwrap();
                if eng.maybe_checkpoint().unwrap().is_some() {
                    compactions += 1;
                }
                // Bounded steady state: at most one threshold plus the
                // segment that absorbed the overshooting frame.
                assert!(
                    eng.journal_disk_bytes()
                        <= opts.checkpoint_bytes + opts.segment_bytes(),
                    "journal {} exceeds bound",
                    eng.journal_disk_bytes()
                );
            }
            assert!(compactions >= 2, "sustained ingest must compact");
            assert_eq!(eng.generation(), compactions);
        }
        let eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts.clone()).unwrap();
        assert_eq!(eng.stats("m").docs, total);
        // Recovery replays only the tail, not O(total writes).
        assert!(
            eng.recovery_report().bytes_replayed
                <= opts.checkpoint_bytes + opts.segment_bytes(),
            "replayed {} bytes",
            eng.recovery_report().bytes_replayed
        );
    }

    #[test]
    fn delta_checkpoint_costs_new_writes_not_live_set() {
        let (mut eng, root) = temp_engine("eng17", true, false);
        eng.create_collection("m");
        for t in 0..800 {
            eng.insert("m", &doc(t, t % 7)).unwrap();
        }
        eng.sync().unwrap();
        let full = eng.checkpoint().unwrap();
        assert!(full.full, "generation 1 must be a full snapshot");
        assert_eq!((full.generation, full.chain_len, full.delta_bytes), (1, 0, 0));
        // After K unchanged records, a generation costs O(new writes).
        for t in 0..10 {
            eng.insert("m", &doc(1000 + t, 1)).unwrap();
        }
        eng.sync().unwrap();
        let delta = eng.checkpoint().unwrap();
        assert!(!delta.full);
        assert_eq!((delta.generation, delta.chain_len), (2, 1));
        assert!(delta.delta_bytes > 0);
        assert_eq!(delta.delta_bytes, delta.checkpoint_bytes);
        assert!(
            delta.delta_bytes * 10 < full.checkpoint_bytes,
            "delta of 10 docs ({} B) must be far below the 800-doc full snapshot ({} B)",
            delta.delta_bytes,
            full.checkpoint_bytes
        );
        assert!(std::path::Path::new(&root).join(delta::delta_file_name(2)).exists());
    }

    #[test]
    fn delta_chain_recovery_folds_base_chain_and_tail() {
        let dir = LocalDir::temp("eng18").unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("m");
            eng.create_index("m", IndexSpec::single("node_id")).unwrap();
            for t in 0..50 {
                eng.insert("m", &doc(t, t % 5)).unwrap();
            }
            eng.sync().unwrap();
            eng.checkpoint().unwrap(); // gen 1: full
            for t in 50..60 {
                eng.insert("m", &doc(t, 1)).unwrap(); // rids 50..59
            }
            eng.sync().unwrap();
            eng.checkpoint().unwrap(); // gen 2: delta (inserts)
            eng.remove("m", 0).unwrap(); // base record
            eng.remove("m", 55).unwrap(); // gen-2 record
            eng.sync().unwrap();
            eng.checkpoint().unwrap(); // gen 3: delta (tombstones)
            for t in 60..64 {
                eng.insert("m", &doc(t, 2)).unwrap();
            }
            eng.sync().unwrap();
            eng.checkpoint().unwrap(); // gen 4: delta
            // Post-chain journal tail, then kill.
            eng.insert("m", &doc(99, 3)).unwrap();
            eng.sync().unwrap();
        }
        let mut eng =
            Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("m").docs, 50 + 10 - 2 + 4 + 1);
        let rep = eng.recovery_report().clone();
        assert_eq!(rep.checkpoint_generation, 4);
        assert_eq!(rep.deltas_folded, 3);
        assert!(rep.delta_bytes_folded > 0);
        assert_eq!(rep.frames_replayed, 1, "only the post-chain tail replays");
        assert!(eng.fetch("m", 0).is_none(), "folded tombstone of a base record");
        assert!(eng.fetch("m", 55).is_none(), "folded tombstone of a chain record");
        assert_eq!(eng.fetch("m", 64).unwrap().get_i64("ts"), Some(99));
        // Indexes rebuilt through base + chain + tail: node 1 appears in
        // 10 base records and 10 chain inserts, minus the removed rid 55.
        let idx = eng.index("m", "node_id_1").unwrap();
        assert_eq!(idx.point(&[&Value::Int(1)]).len(), 19);
        // Rid allocation continues past every folded generation.
        assert_eq!(eng.insert("m", &doc(100, 4)).unwrap(), 65);
    }

    #[test]
    fn chain_rebases_into_full_snapshot_and_deletes_deltas() {
        let opts = EngineOptions {
            journal: true,
            compress_checkpoints: false,
            checkpoint_bytes: 0,
            journal_segments: 4,
            full_checkpoint_chain: 2,
            ..EngineOptions::default()
        };
        let dir = LocalDir::temp("eng19").unwrap();
        let root = dir.describe();
        let mut eng = Engine::open_with(Box::new(dir), opts.clone()).unwrap();
        eng.create_collection("m");
        eng.insert("m", &doc(0, 0)).unwrap();
        eng.sync().unwrap();
        assert!(eng.checkpoint().unwrap().full); // gen 1
        for g in 0..2i64 {
            eng.insert("m", &doc(10 + g, 0)).unwrap();
            eng.sync().unwrap();
            let ck = eng.checkpoint().unwrap();
            assert!(!ck.full, "generation {} should be a delta", ck.generation);
        }
        assert_eq!(eng.chain_len(), 2);
        assert!(eng.chain_disk_bytes() > 0);
        assert!(std::path::Path::new(&root).join(delta::delta_file_name(3)).exists());
        // Chain at the threshold: the next checkpoint rebases.
        eng.insert("m", &doc(20, 0)).unwrap();
        eng.sync().unwrap();
        let ck = eng.checkpoint().unwrap();
        assert!(ck.full);
        assert_eq!((ck.generation, ck.chain_len), (4, 0));
        assert_eq!(eng.base_generation(), 4);
        assert_eq!(eng.chain_disk_bytes(), 0);
        for g in 2..=3 {
            assert!(
                !std::path::Path::new(&root).join(delta::delta_file_name(g)).exists(),
                "superseded delta {g} must be deleted by the rebase"
            );
        }
        drop(eng);
        let eng = Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
        assert_eq!(eng.stats("m").docs, 4);
        assert_eq!(eng.recovery_report().deltas_folded, 0);
        assert_eq!(eng.recovery_report().checkpoint_generation, 4);
    }

    #[test]
    fn chain_zero_writes_full_snapshots_only() {
        let opts = EngineOptions { full_checkpoint_chain: 0, ..EngineOptions::default() };
        let dir = LocalDir::temp("eng20").unwrap();
        let root = dir.describe();
        let mut eng = Engine::open_with(Box::new(dir), opts).unwrap();
        eng.create_collection("m");
        for g in 0..3i64 {
            eng.insert("m", &doc(g, 0)).unwrap();
            eng.sync().unwrap();
            let ck = eng.checkpoint().unwrap();
            assert!(ck.full, "chain=0 keeps the pre-delta all-full behaviour");
            assert_eq!(ck.delta_bytes, 0);
        }
        let deltas = std::fs::read_dir(&root)
            .unwrap()
            .filter(|e| {
                e.as_ref().unwrap().file_name().to_string_lossy().starts_with("delta-")
            })
            .count();
        assert_eq!(deltas, 0);
    }

    #[test]
    fn failed_checkpoint_write_does_not_gap_the_chain() {
        // The shard's compaction hook swallows checkpoint errors and
        // retries on the next group commit, so a failed write must not
        // mint a generation: a minted-but-unwritten generation would
        // either gap the delta chain (unopenable store) or chain a
        // delta onto a base that does not exist (silent data loss).
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;

        struct FlakyDir {
            inner: LocalDir,
            fail_next_atomic: Arc<AtomicBool>,
        }
        impl StorageDir for FlakyDir {
            fn create(&self, name: &str) -> Result<Box<dyn StorageFile>> {
                self.inner.create(name)
            }
            fn append_to(&self, name: &str) -> Result<Box<dyn StorageFile>> {
                self.inner.append_to(name)
            }
            fn read(&self, name: &str) -> Result<Vec<u8>> {
                self.inner.read(name)
            }
            fn write_atomic(&self, name: &str, bytes: &[u8]) -> Result<()> {
                if self.fail_next_atomic.swap(false, Ordering::SeqCst) {
                    bail!("injected checkpoint write failure");
                }
                self.inner.write_atomic(name, bytes)
            }
            fn exists(&self, name: &str) -> bool {
                self.inner.exists(name)
            }
            fn remove(&self, name: &str) -> Result<()> {
                self.inner.remove(name)
            }
            fn list(&self) -> Result<Vec<String>> {
                self.inner.list()
            }
            fn describe(&self) -> String {
                self.inner.describe()
            }
        }

        let inner = LocalDir::temp("eng23").unwrap();
        let root = inner.describe();
        let fail = Arc::new(AtomicBool::new(false));
        let dir = FlakyDir { inner, fail_next_atomic: fail.clone() };
        let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
        eng.create_collection("m");

        // Generation 1 (full) fails: nothing minted, retry is still full.
        eng.insert("m", &doc(1, 1)).unwrap();
        eng.sync().unwrap();
        fail.store(true, Ordering::SeqCst);
        assert!(eng.checkpoint().is_err());
        assert_eq!(eng.generation(), 0, "failed write must not mint a generation");
        let ck = eng.checkpoint().unwrap();
        assert!(ck.full);
        assert_eq!(ck.generation, 1);

        // A failed delta write must not gap the chain either.
        eng.insert("m", &doc(2, 2)).unwrap();
        eng.sync().unwrap();
        fail.store(true, Ordering::SeqCst);
        assert!(eng.checkpoint().is_err());
        assert_eq!(eng.generation(), 1);
        eng.insert("m", &doc(3, 3)).unwrap();
        eng.sync().unwrap();
        let ck = eng.checkpoint().unwrap();
        assert!(!ck.full);
        assert_eq!(ck.generation, 2, "retry must reuse the unminted generation");
        drop(eng);

        let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("m").docs, 3);
        assert_eq!(eng.recovery_report().checkpoint_generation, 2);
        assert_eq!(eng.recovery_report().deltas_folded, 1);
    }

    #[test]
    fn empty_delta_generation_round_trips() {
        let dir = LocalDir::temp("eng21").unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("m");
            eng.insert("m", &doc(1, 1)).unwrap();
            eng.sync().unwrap();
            eng.checkpoint().unwrap(); // gen 1: full
            let ck = eng.checkpoint().unwrap(); // gen 2: delta of nothing
            assert!(!ck.full);
        }
        let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("m").docs, 1);
        assert_eq!(eng.recovery_report().deltas_folded, 1);
    }

    #[test]
    fn post_recovery_delta_includes_replayed_tail() {
        // Journal frames replayed at open are durable-but-uncheckpointed
        // work: the first post-recovery delta must carry them, because it
        // truncates the journal that held them.
        let dir = LocalDir::temp("eng22").unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("m");
            for t in 0..5 {
                eng.insert("m", &doc(t, 1)).unwrap();
            }
            eng.sync().unwrap();
            eng.checkpoint().unwrap(); // gen 1: full
            eng.insert("m", &doc(10, 2)).unwrap();
            eng.sync().unwrap();
            // Kill with one frame in the journal tail.
        }
        {
            let mut eng =
                Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
            assert_eq!(eng.recovery_report().frames_replayed, 1);
            let ck = eng.checkpoint().unwrap(); // gen 2: delta, truncates the tail
            assert!(!ck.full);
            assert!(ck.delta_bytes > 0, "the replayed frame must be in the delta");
        }
        let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("m").docs, 6);
        assert_eq!(eng.fetch("m", 5).unwrap().get_i64("ts"), Some(10));
        assert_eq!(eng.recovery_report().frames_replayed, 0);
        assert_eq!(eng.recovery_report().deltas_folded, 1);
    }

    #[test]
    fn frame_and_byte_counters_track_syncs() {
        let (mut eng, _) = temp_engine("eng16", true, false);
        eng.create_collection("m");
        eng.insert("m", &doc(1, 1)).unwrap();
        eng.insert_many("m", &[doc(2, 2), doc(3, 3)]).unwrap();
        assert_eq!(eng.frames_since_checkpoint(), 0, "nothing durable yet");
        eng.sync().unwrap();
        assert_eq!(eng.frames_since_checkpoint(), 2);
        assert!(eng.journal_bytes_since_checkpoint() > 0);
        assert_eq!(
            eng.journal_bytes_since_checkpoint(),
            eng.journal_disk_bytes()
        );
        eng.checkpoint().unwrap();
        assert_eq!(eng.frames_since_checkpoint(), 0);
        assert_eq!(eng.journal_bytes_since_checkpoint(), 0);
        assert_eq!(eng.journal_disk_bytes(), 0);
    }

    #[test]
    fn remove_many_is_one_atomic_frame_and_replays() {
        let dir = LocalDir::temp("eng24").unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("m");
            eng.create_index("m", IndexSpec::single("node_id")).unwrap();
            let rids = eng
                .insert_many("m", &(0..10).map(|t| doc(t, t % 2)).collect::<Vec<_>>())
                .unwrap();
            eng.sync().unwrap();
            let before = eng.frames_since_checkpoint();
            let docs = eng.remove_many("m", &rids[2..7]).unwrap();
            assert_eq!(docs.len(), 5);
            eng.sync().unwrap();
            assert_eq!(
                eng.frames_since_checkpoint(),
                before + 1,
                "one frame for the whole range"
            );
            assert_eq!(eng.stats("m").docs, 5);
            // Unknown rid fails without mutating anything.
            assert!(eng.remove_many("m", &[999]).is_err());
            assert_eq!(eng.stats("m").docs, 5);
        }
        let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("m").docs, 5, "replayed range delete must be exact");
        assert!(eng.fetch("m", 3).is_none());
        assert_eq!(eng.fetch("m", 8).unwrap().get_i64("ts"), Some(8));
    }

    #[test]
    fn move_many_is_atomic_and_allocates_fresh_rids() {
        let dir = LocalDir::temp("eng25").unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("staged");
            eng.create_collection("m");
            eng.create_index("m", IndexSpec::single("node_id")).unwrap();
            eng.insert_many("m", &[doc(100, 9)]).unwrap(); // live rid 0
            let rids = eng
                .insert_many("staged", &(0..6).map(|t| doc(t, 1)).collect::<Vec<_>>())
                .unwrap();
            eng.sync().unwrap();
            let moved = eng.move_many("staged", "m", &rids).unwrap();
            assert_eq!(moved, (1..=6).collect::<Vec<u64>>());
            eng.sync().unwrap();
            assert_eq!(eng.stats("staged").docs, 0);
            assert_eq!(eng.stats("m").docs, 7);
            // The destination indexes cover the moved records.
            let idx = eng.index("m", "node_id_1").unwrap();
            assert_eq!(idx.point(&[&Value::Int(1)]).len(), 6);
        }
        let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("staged").docs, 0, "replayed move must empty the source");
        assert_eq!(eng.stats("m").docs, 7);
        assert_eq!(eng.fetch("m", 4).unwrap().get_i64("ts"), Some(3));
    }

    #[test]
    fn next_record_id_predicts_the_move_many_run() {
        // The shard publish path pre-masks `[next_record_id, MAX]`
        // before `move_many` commits and then tightens to the moved
        // rids — that is only sound if, with no interleaved write, the
        // move lands on exactly the predicted contiguous run.
        let (mut eng, _) = temp_engine("eng26b", false, false);
        eng.create_collection("staged");
        eng.create_collection("m");
        assert_eq!(eng.next_record_id("m"), 0);
        assert_eq!(eng.next_record_id("missing"), 0);
        eng.insert_many("m", &(0..3).map(|t| doc(t, 0)).collect::<Vec<_>>())
            .unwrap();
        let staged = eng
            .insert_many("staged", &(0..5).map(|t| doc(t, 1)).collect::<Vec<_>>())
            .unwrap();
        let predicted = eng.next_record_id("m");
        assert_eq!(predicted, 3);
        let moved = eng.move_many("staged", "m", &staged).unwrap();
        assert_eq!(
            moved,
            (predicted..predicted + 5).collect::<Vec<RecordId>>(),
            "move must fill exactly the predicted rid run"
        );
        // Removes never give rids back: the prediction only grows.
        eng.remove_many("m", &moved).unwrap();
        assert_eq!(eng.next_record_id("m"), predicted + 5);
    }

    #[test]
    fn scan_from_resumes_after_rid() {
        let (mut eng, _) = temp_engine("eng26", false, false);
        eng.create_collection("m");
        for t in 0..10 {
            eng.insert("m", &doc(t, 0)).unwrap();
        }
        let all: Vec<RecordId> = eng.scan_from("m", None).map(|(r, _)| r).collect();
        assert_eq!(all, (0..10).collect::<Vec<u64>>());
        let tail: Vec<RecordId> = eng.scan_from("m", Some(6)).map(|(r, _)| r).collect();
        assert_eq!(tail, vec![7, 8, 9]);
        assert_eq!(eng.scan_from("m", Some(99)).count(), 0);
        assert_eq!(eng.scan_from("none", None).count(), 0);
    }

    #[test]
    fn parallel_index_maintenance_matches_inline() {
        // A batch above the parallel threshold with two indexes takes
        // the scoped-thread path; per-document inserts take the inline
        // path. Both must produce identical store and index contents.
        let (mut par, _) = temp_engine("eng27a", false, false);
        let (mut seq, _) = temp_engine("eng27b", false, false);
        for eng in [&mut par, &mut seq] {
            eng.create_collection("m");
            eng.create_index("m", IndexSpec::single("ts")).unwrap();
            eng.create_index("m", IndexSpec::single("node_id")).unwrap();
        }
        let docs: Vec<Document> = (0..(INDEX_PARALLEL_MIN_DOCS as i64 * 2))
            .map(|t| doc(t, t % 13))
            .collect();
        par.insert_many("m", &docs).unwrap();
        for d in &docs {
            seq.insert("m", d).unwrap();
        }
        assert_eq!(par.stats("m"), seq.stats("m"));
        for node in 0..13i64 {
            assert_eq!(
                par.index("m", "node_id_1").unwrap().point(&[&Value::Int(node)]),
                seq.index("m", "node_id_1").unwrap().point(&[&Value::Int(node)]),
            );
        }
    }

    #[test]
    fn snapshot_reads_are_stable_across_removes_and_inserts() {
        let (mut eng, _) = temp_engine("mvcc1", false, false);
        eng.create_collection("m");
        let rids = eng.insert_many("m", &(0..8).map(|t| doc(t, 1)).collect::<Vec<_>>()).unwrap();
        let reader = eng.reader();
        let snap = reader.snapshot();
        // Writer keeps committing: removes two, inserts three.
        eng.remove_many("m", &rids[0..2]).unwrap();
        eng.insert_many("m", &(100..103).map(|t| doc(t, 2)).collect::<Vec<_>>()).unwrap();
        // Latest view tracks the live set…
        assert_eq!(eng.stats("m").docs, 9);
        assert_eq!(reader.latest().scan_raw_from("m", None).count(), 9);
        // …while the snapshot still reads its frozen world.
        let view = reader.view(&snap).unwrap();
        assert_eq!(view.scan_raw_from("m", None).count(), 8);
        assert_eq!(view.doc_count("m"), 8);
        assert!(view.fetch_raw("m", rids[0]).is_some(), "removed record visible at snapshot");
        assert!(reader.latest().fetch_raw("m", rids[0]).is_none());
    }

    #[test]
    fn reclaim_waits_for_oldest_open_snapshot() {
        let (mut eng, _) = temp_engine("mvcc2", false, false);
        eng.create_collection("m");
        let rids = eng.insert_many("m", &(0..4).map(|t| doc(t, 1)).collect::<Vec<_>>()).unwrap();
        let reader = eng.reader();
        let snap = reader.snapshot();
        eng.remove_many("m", &rids[..2]).unwrap();
        assert_eq!(eng.garbage_len(), 2);
        // The open snapshot can still see the dead versions: no reclaim.
        assert_eq!(eng.reclaim(), 0);
        assert_eq!(eng.garbage_len(), 2);
        assert_eq!(eng.snapshots_open(), 1);
        drop(snap);
        assert_eq!(eng.snapshots_open(), 0);
        assert_eq!(eng.reclaim(), 2);
        assert_eq!(eng.garbage_len(), 0);
        // Physically gone: even a direct probe finds nothing.
        assert!(eng.fetch_raw("m", rids[0]).is_none());
    }

    #[test]
    fn retention_expires_stale_snapshots_with_clean_error() {
        let opts = EngineOptions { snapshot_retention: 3, ..EngineOptions::default() };
        let dir = LocalDir::temp("mvcc3").unwrap();
        let mut eng = Engine::open_with(Box::new(dir), opts).unwrap();
        eng.create_collection("m");
        let rid = eng.insert("m", &doc(0, 0)).unwrap();
        let reader = eng.reader();
        let snap = reader.snapshot(); // pinned at epoch 1
        eng.remove("m", rid).unwrap();
        for t in 1..6 {
            eng.insert("m", &doc(t, 0)).unwrap(); // epochs 3..=7
        }
        // The stale pin no longer holds reclamation back…
        assert_eq!(eng.reclaim(), 1);
        // …and the expired snapshot fails retryably instead of reading
        // a half-reclaimed state.
        let err = reader.view(&snap).expect_err("snapshot must be expired");
        assert!(err.floor > err.at, "{err}");
        // A fresh snapshot works.
        let snap2 = reader.snapshot();
        assert_eq!(reader.view(&snap2).unwrap().doc_count("m"), 5);
    }

    #[test]
    fn checkpoint_persists_only_live_records() {
        let dir = LocalDir::temp("mvcc4").unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("m");
            let rids =
                eng.insert_many("m", &(0..6).map(|t| doc(t, 1)).collect::<Vec<_>>()).unwrap();
            eng.sync().unwrap();
            let reader = eng.reader();
            let _snap = reader.snapshot(); // keeps the dead versions retained
            eng.remove_many("m", &rids[..3]).unwrap();
            eng.sync().unwrap();
            assert_eq!(eng.reclaim(), 0, "open snapshot holds the garbage");
            eng.checkpoint().unwrap();
        }
        let eng = Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        assert_eq!(eng.stats("m").docs, 3, "dead-but-retained versions must not persist");
        assert!(eng.fetch("m", 0).is_none());
        assert_eq!(eng.fetch("m", 5).unwrap().get_i64("ts"), Some(5));
        assert_eq!(eng.garbage_len(), 0);
    }

    #[test]
    fn move_many_flips_atomically_under_snapshots() {
        let (mut eng, _) = temp_engine("mvcc5", false, false);
        eng.create_collection("src");
        eng.create_collection("dst");
        let rids = eng.insert_many("src", &(0..5).map(|t| doc(t, 1)).collect::<Vec<_>>()).unwrap();
        let reader = eng.reader();
        let snap = reader.snapshot();
        eng.move_many("src", "dst", &rids).unwrap();
        // The snapshot sees the pre-flip world exactly.
        let view = reader.view(&snap).unwrap();
        assert_eq!(view.doc_count("src"), 5);
        assert_eq!(view.doc_count("dst"), 0);
        // Latest sees the post-flip world exactly.
        let latest = reader.latest();
        assert_eq!(latest.doc_count("src"), 0);
        assert_eq!(latest.doc_count("dst"), 5);
    }

    #[test]
    fn index_backfill_copies_version_stamps() {
        let (mut eng, _) = temp_engine("mvcc6", false, false);
        eng.create_collection("m");
        let rids = eng.insert_many("m", &(0..4).map(|t| doc(t, 7)).collect::<Vec<_>>()).unwrap();
        let reader = eng.reader();
        let snap = reader.snapshot();
        eng.remove("m", rids[0]).unwrap();
        // Index created *after* the remove: the backfill must copy the
        // dead-but-retained record's stamps, or a snapshot query planned
        // over it would miss a record a table scan at its epoch finds.
        eng.create_index("m", IndexSpec::single("node_id")).unwrap();
        let view = reader.view(&snap).unwrap();
        let idx = view.index("m", "node_id_1").unwrap();
        assert_eq!(idx.point_len_at(&[&Value::Int(7)], view.at()), 4);
        let latest = reader.latest();
        let idx = latest.index("m", "node_id_1").unwrap();
        assert_eq!(idx.point_len_at(&[&Value::Int(7)], latest.at()), 3);
        assert_eq!(idx.point(&[&Value::Int(7)]).len(), 3);
    }

    #[test]
    fn each_engine_call_commits_one_epoch() {
        let (mut eng, _) = temp_engine("mvcc7", false, false);
        eng.create_collection("m");
        assert_eq!(eng.epoch(), 0);
        eng.insert_many("m", &(0..10).map(|t| doc(t, 1)).collect::<Vec<_>>()).unwrap();
        assert_eq!(eng.epoch(), 1, "a whole batch is one commit");
        eng.insert("m", &doc(99, 1)).unwrap();
        assert_eq!(eng.epoch(), 2);
        eng.remove_many("m", &[0, 1]).unwrap();
        assert_eq!(eng.epoch(), 3);
    }

    #[test]
    fn concurrent_readers_see_only_committed_batches() {
        // A writer thread commits batches while reader threads snapshot
        // and drain: every observed count must be a multiple of the
        // batch size (no torn batch is ever visible).
        let (mut eng, _) = temp_engine("mvcc8", false, false);
        eng.create_collection("m");
        const BATCH: usize = 32;
        let reader = eng.reader();
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..3)
                .map(|_| {
                    let r = reader.clone();
                    s.spawn(move || {
                        for _ in 0..200 {
                            let snap = r.snapshot();
                            let view = r.view(&snap).unwrap();
                            let n = view.scan_raw_from("m", None).count();
                            assert_eq!(n % BATCH, 0, "torn batch visible: {n}");
                            assert_eq!(view.doc_count("m") as usize, n);
                        }
                    })
                })
                .collect();
            for b in 0..40i64 {
                let batch: Vec<Document> =
                    (0..BATCH as i64).map(|i| doc(b * BATCH as i64 + i, 1)).collect();
                eng.insert_many("m", &batch).unwrap();
                eng.reclaim();
            }
            for h in handles {
                // lint: allow(panic, test thread join)
                h.join().unwrap();
            }
        });
        assert_eq!(eng.stats("m").docs, 40 * BATCH as u64);
    }
}
