//! Named metric registry shared across threads.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::Histogram;
use crate::json::Value;

/// Monotonic counter.
#[derive(Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous gauge.
#[derive(Clone, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A registry of named counters/gauges/histograms.
///
/// Cloning a registry shares the underlying metrics (it's an `Arc` of
/// maps); component constructors take a registry and register what they
/// need up front.
#[derive(Clone, Default)]
pub struct Registry {
    inner: Arc<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: Mutex<BTreeMap<String, Counter>>,
    gauges: Mutex<BTreeMap<String, Gauge>>,
    histograms: Mutex<BTreeMap<String, Arc<Mutex<Histogram>>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Counter {
        self.inner
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        self.inner
            .gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Record one value into a named histogram.
    pub fn observe(&self, name: &str, value: u64) {
        let h = self
            .inner
            .histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Mutex::new(Histogram::new())))
            .clone();
        h.lock().unwrap().record(value);
    }

    /// Snapshot a histogram by name (empty if never observed).
    pub fn histogram(&self, name: &str) -> Histogram {
        self.inner
            .histograms
            .lock()
            .unwrap()
            .get(name)
            .map(|h| h.lock().unwrap().clone())
            .unwrap_or_default()
    }

    /// All counter values (snapshot).
    pub fn counter_values(&self) -> BTreeMap<String, u64> {
        self.inner
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.get()))
            .collect()
    }

    /// Serialize a full snapshot (counters, gauges, histogram summaries).
    pub fn snapshot_json(&self) -> Value {
        let mut counters = Value::object();
        for (k, v) in self.counter_values() {
            counters.set(&k, v);
        }
        let mut gauges = Value::object();
        for (k, v) in self.inner.gauges.lock().unwrap().iter() {
            gauges.set(k, v.get());
        }
        let mut hists = Value::object();
        for (k, h) in self.inner.histograms.lock().unwrap().iter() {
            let h = h.lock().unwrap();
            let mut o = Value::object();
            o.set("count", h.count())
                .set("mean", h.mean())
                .set("p50", h.p50())
                .set("p95", h.p95())
                .set("p99", h.p99())
                .set("max", h.max());
            hists.set(k, o);
        }
        let mut root = Value::object();
        root.set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", hists);
        root
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_shared_by_name() {
        let r = Registry::new();
        let a = r.counter("ops");
        let b = r.counter("ops");
        a.add(2);
        b.inc();
        assert_eq!(r.counter("ops").get(), 3);
    }

    #[test]
    fn gauges_set_and_add() {
        let r = Registry::new();
        r.gauge("depth").set(5);
        r.gauge("depth").add(-2);
        assert_eq!(r.gauge("depth").get(), 3);
    }

    #[test]
    fn histograms_observe_and_snapshot() {
        let r = Registry::new();
        for v in [10u64, 20, 30] {
            r.observe("lat", v);
        }
        let h = r.histogram("lat");
        assert_eq!(h.count(), 3);
        assert!(r.histogram("nonexistent").count() == 0);
    }

    #[test]
    fn cloned_registry_shares_metrics() {
        let r = Registry::new();
        let r2 = r.clone();
        r.counter("x").inc();
        assert_eq!(r2.counter("x").get(), 1);
    }

    #[test]
    fn snapshot_is_json_object() {
        let r = Registry::new();
        r.counter("a").inc();
        r.gauge("g").set(7);
        r.observe("h", 42);
        let v = r.snapshot_json();
        assert_eq!(v.at(&["counters", "a"]).unwrap().as_u64(), Some(1));
        assert_eq!(v.at(&["gauges", "g"]).unwrap().as_i64(), Some(7));
        assert_eq!(v.at(&["histograms", "h", "count"]).unwrap().as_u64(), Some(1));
    }

    #[test]
    fn threaded_counting() {
        let r = Registry::new();
        let mut handles = vec![];
        for _ in 0..4 {
            let c = r.counter("n");
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.counter("n").get(), 4000);
    }
}
