//! Jittered exponential backoff for retry loops.
//!
//! Routers retry on `StaleVersion`, `MigrationInFlight`, and
//! `NotPrimary`; spinning on those in a tight loop burns a core and
//! hammers the shard mailbox exactly when the cluster is busiest
//! (mid-migration, mid-election). [`Backoff`] centralises the wait
//! policy: exponential growth from a small base to a cap, with full
//! jitter (each sleep is uniform in `(0, step]`) so concurrent
//! retriers decorrelate instead of thundering back in lockstep.

use std::collections::hash_map::RandomState;
use std::hash::{BuildHasher, Hasher};
use std::time::Duration;

use crate::util::SplitMix64;

/// Exponential backoff state for one retry loop.
///
/// Construct once per logical operation, call [`Backoff::wait`] before
/// each retry. The first wait is at most `base_us`, doubling per call
/// up to `cap_us`.
#[derive(Debug)]
pub struct Backoff {
    step_us: u64,
    cap_us: u64,
    rng: SplitMix64,
    attempts: u32,
}

impl Backoff {
    /// A backoff starting at `base_us` microseconds, capped at `cap_us`.
    pub fn new(base_us: u64, cap_us: u64) -> Self {
        let base = base_us.max(1);
        Backoff {
            step_us: base,
            cap_us: cap_us.max(base),
            // Seed from the process-random hasher state so concurrent
            // loops jitter differently without needing a clock or `rand`.
            rng: SplitMix64::new({
                let mut h = RandomState::new().build_hasher();
                h.write_u64(base);
                h.finish() | 1
            }),
            attempts: 0,
        }
    }

    /// Number of waits taken so far.
    pub fn attempts(&self) -> u32 {
        self.attempts
    }

    /// The duration the next [`Backoff::wait`] call would sleep, without
    /// sleeping. Full jitter: uniform in `(0, step]`.
    pub fn next_delay(&mut self) -> Duration {
        let jittered = self.rng.next_u64() % self.step_us + 1;
        Duration::from_micros(jittered)
    }

    /// Sleep for the current jittered step, then double the step
    /// (saturating at the cap).
    pub fn wait(&mut self) {
        let delay = self.next_delay();
        self.attempts += 1;
        self.step_us = (self.step_us * 2).min(self.cap_us);
        std::thread::sleep(delay);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delays_bounded_by_growing_step() {
        let mut b = Backoff::new(100, 800);
        for expect_cap in [100u64, 200, 400, 800, 800, 800] {
            let d = b.next_delay();
            assert!(d.as_micros() >= 1, "jitter must be nonzero");
            assert!(
                d.as_micros() as u64 <= expect_cap,
                "delay {d:?} exceeds step cap {expect_cap}µs"
            );
            // Advance the step the way wait() would, without sleeping.
            b.step_us = (b.step_us * 2).min(b.cap_us);
        }
    }

    #[test]
    fn zero_base_clamps_to_one() {
        let mut b = Backoff::new(0, 0);
        let d = b.next_delay();
        assert_eq!(d.as_micros(), 1);
    }

    #[test]
    fn attempts_count_waits() {
        let mut b = Backoff::new(1, 2);
        assert_eq!(b.attempts(), 0);
        b.wait();
        b.wait();
        assert_eq!(b.attempts(), 2);
    }
}
