//! Server processes: each cluster role runs as a thread with an mpsc
//! event loop (the live-mode analogue of one process per processing
//! element, paper §3.2).

pub mod config;
pub mod read;
pub mod replica;
pub mod router;
pub mod shard;

pub use config::ConfigServer;
pub use read::{ReadContext, ReadRequest, ReaderPool};
pub use replica::{ReplicaConfig, Role};
pub use router::{InsertManyReply, Router, RouterMailbox, RouterRequest, RouterStatsReply};
pub use shard::ShardServer;
