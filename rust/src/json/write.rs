//! JSON writer: compact and pretty forms; deterministic key order
//! (objects are `BTreeMap`s).

use super::Value;

/// Compact encoding.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, None, 0, &mut out);
    out
}

/// Pretty encoding with 2-space indent and trailing newline.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, Some(2), 0, &mut out);
    out.push('\n');
    out
}

fn write_value(v: &Value, indent: Option<usize>, depth: usize, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Int(i) => out.push_str(&i.to_string()),
        Value::Num(f) => write_f64(*f, out),
        Value::Str(s) => write_string(s, out),
        Value::Array(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(indent, depth + 1, out);
                write_value(item, indent, depth + 1, out);
            }
            newline_indent(indent, depth, out);
            out.push(']');
        }
        Value::Object(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(indent, depth + 1, out);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, indent, depth + 1, out);
            }
            newline_indent(indent, depth, out);
            out.push('}');
        }
    }
}

fn newline_indent(indent: Option<usize>, depth: usize, out: &mut String) {
    if let Some(w) = indent {
        out.push('\n');
        out.push_str(&" ".repeat(w * depth));
    }
}

fn write_f64(f: f64, out: &mut String) {
    if f.is_finite() {
        // Shortest round-trip representation Rust offers.
        let s = format!("{f}");
        out.push_str(&s);
        if !s.contains('.') && !s.contains('e') && !s.contains('E') {
            out.push_str(".0");
        }
    } else {
        // JSON has no inf/nan; encode as null (documented limitation).
        out.push_str("null");
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::parse;
    use super::*;

    #[test]
    fn round_trip_compact() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":null,"d":true}}"#;
        let v = parse(src).unwrap();
        assert_eq!(to_string(&v), src);
    }

    #[test]
    fn round_trip_pretty() {
        let v = parse(r#"{"a": [1, {"b": "x"}], "z": -3}"#).unwrap();
        let pretty = to_string_pretty(&v);
        assert_eq!(parse(&pretty).unwrap(), v);
        assert!(pretty.contains("\n  \"a\""));
    }

    #[test]
    fn float_round_trips_exactly() {
        for f in [0.1, 1.5, 1e-9, 123456.789, -2.25, 1e300] {
            let v = Value::Num(f);
            let back = parse(&to_string(&v)).unwrap();
            assert_eq!(back.as_f64(), Some(f), "{f}");
        }
    }

    #[test]
    fn whole_float_keeps_distinction() {
        // Value::Num(2.0) prints "2.0" so it parses back as a float.
        assert_eq!(to_string(&Value::Num(2.0)), "2.0");
        assert_eq!(to_string(&Value::Int(2)), "2");
    }

    #[test]
    fn escapes() {
        let v = Value::Str("a\"b\\c\nd\u{0001}".into());
        assert_eq!(to_string(&v), "\"a\\\"b\\\\c\\nd\\u0001\"");
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }

    #[test]
    fn nonfinite_becomes_null() {
        assert_eq!(to_string(&Value::Num(f64::NAN)), "null");
        assert_eq!(to_string(&Value::Num(f64::INFINITY)), "null");
    }
}
