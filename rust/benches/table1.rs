//! T1 — regenerate the paper's Table 1 (nodes → days of data), extended
//! with the role assignment rule of §4 and realized corpus sizes.

use hpcstore::benchkit::Report;
use hpcstore::config::{Topology, WorkloadConfig, TABLE1};
use hpcstore::util::fmt::{human_bytes, human_count};
use hpcstore::workload::csvstore;
use hpcstore::workload::ovis::OvisGenerator;

fn main() {
    let monitored = 2_048u32; // paper: ~27k Blue Waters nodes, sim-scaled
    let mut report = Report::new(&format!(
        "Table 1 — days of data per cluster size (corpus scaled to {monitored} monitored nodes; paper: 27k nodes, 70B rows, 200TB CSV)"
    ));
    report.set_custom(
        ["nodes", "days", "config", "shards", "routers", "client PEs", "docs", "CSV bytes"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    for (nodes, days) in TABLE1 {
        let topo = Topology::paper_preset(nodes).unwrap();
        let wl = WorkloadConfig { monitored_nodes: monitored, days, ..Default::default() };
        let gen = OvisGenerator::new(wl.clone());
        report.add_row(vec![
            nodes.to_string(),
            format!("{days}"),
            topo.config_servers.to_string(),
            topo.shards.to_string(),
            topo.routers.to_string(),
            topo.client_pes().to_string(),
            human_count(wl.total_docs()),
            human_bytes(csvstore::corpus_bytes(&gen)),
        ]);
    }
    report.print();
    println!("\npaper Table 1: 32→3 days, 64→7, 128→14, 256→14 ✓ (fixed preset)");
}
