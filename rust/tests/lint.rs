//! Tier-1 driver for `pallas-lint`: the real source tree must pass
//! every rule family with an empty violation list. A failure prints
//! each finding as `file:line: [rule] message`.
//!
//! The fixture-based self-tests (known-bad trees must be flagged) live
//! as unit tests inside `src/analysis/*`; this integration test pins
//! the *repository itself* to the invariants.

use hpcstore::analysis::{run_all, SourceTree};

fn repo_root() -> std::path::PathBuf {
    // CARGO_MANIFEST_DIR is <repo>/rust.
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .to_path_buf()
}

#[test]
fn source_tree_passes_all_lint_rules() {
    let tree = SourceTree::from_repo_root(&repo_root()).expect("repo readable");
    let violations = run_all(&tree);
    assert!(
        violations.is_empty(),
        "pallas-lint found {} violation(s):\n{}",
        violations.len(),
        violations.iter().map(|v| format!("  {v}\n")).collect::<String>()
    );
}

#[test]
fn lint_surface_is_loaded() {
    // Guard against the driver silently passing because the tree came
    // up empty (e.g. a path regression in SourceTree::from_repo_root).
    let tree = SourceTree::from_repo_root(&repo_root()).expect("repo readable");
    for required in [
        "rust/src/mongo/wire.rs",
        "rust/src/mongo/storage/engine.rs",
        "rust/src/metrics/registry.rs",
        "rust/src/config/mod.rs",
        "rust/src/main.rs",
        "docs/ARCHITECTURE.md",
        "docs/EXPERIMENTS.md",
    ] {
        assert!(tree.content(required).is_some(), "missing {required} from lint surface");
    }
}

#[test]
fn seeded_violation_fails_with_file_line_diagnostic() {
    // Acceptance check from the issue: a deliberate violation must
    // produce a file:line diagnostic. Seed a typo'd bare metric
    // literal into a copy of the real tree.
    let mut tree = SourceTree::from_repo_root(&repo_root()).expect("repo readable");
    tree.add(
        "rust/src/mongo/server/seeded.rs",
        "fn f(m: &Registry) { m.counter(\"shard.checkpionts\").inc(); }\n",
    );
    let violations = run_all(&tree);
    let seeded: Vec<_> = violations
        .iter()
        .filter(|v| v.file == "rust/src/mongo/server/seeded.rs")
        .collect();
    assert_eq!(seeded.len(), 1, "{violations:?}");
    assert_eq!(seeded[0].line, 1);
    assert!(seeded[0].to_string().contains("seeded.rs:1:"), "{}", seeded[0]);
    assert!(seeded[0].message.contains("shard.checkpionts"));
}
