"""Pallas kernel: per-column min/max/sum over an ingest metric batch.

At ingest each shard maintains collection statistics (per-metric min /
max / mean) used by the query planner and the balancer's load estimate.
The batch is a dense ``f32[B, M]`` tile; the reduction runs column-wise
over VPU lanes. B=4096, M=16 → 256 KiB VMEM for the input tile, single
grid step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stats_kernel(x_ref, min_ref, max_ref, sum_ref):
    x = x_ref[...]
    min_ref[...] = jnp.min(x, axis=0)
    max_ref[...] = jnp.max(x, axis=0)
    sum_ref[...] = jnp.sum(x, axis=0)


@jax.jit
def batch_stats(metrics):
    """Column statistics for one ingest batch.

    Args:
      metrics: f32[B, M].

    Returns:
      (min f32[M], max f32[M], mean f32[M]).
    """
    b, m = metrics.shape
    mn, mx, sm = pl.pallas_call(
        _stats_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(metrics)
    return mn, mx, sm / jnp.float32(b)
