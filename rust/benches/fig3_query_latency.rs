//! F3 — regenerate Figure 3: concurrent conditional-find latency vs
//! cluster size.
//!
//! Paper: "cluster size maintains a similar query performance for
//! various MongoDB cluster sizes ... each cluster size is servicing
//! more concurrent quarries" (32 nodes → up to 64 concurrent finds,
//! 64 → up to 128, and so on). The DES scales concurrency with client
//! PEs and the latency distribution should stay roughly flat.

use hpcstore::benchkit::{quick_mode, Report};
use hpcstore::config::WorkloadConfig;
use hpcstore::metrics::Registry;
use hpcstore::mongo::cluster::{Cluster, ClusterSpec};
use hpcstore::mongo::storage::index::IndexSpec;
use hpcstore::mongo::storage::LocalDir;
use hpcstore::runtime::Kernels;
use hpcstore::sim::{ClusterSim, CostModel, SimSpec};
use hpcstore::util::fmt::human_duration_ns;
use hpcstore::workload::jobs::generate_jobs;
use hpcstore::workload::ovis::OvisGenerator;
use hpcstore::workload::{IngestDriver, QueryDriver};

fn main() {
    let cost = CostModel::load_or_default(std::path::Path::new("artifacts")).with_network_floor();
    let mut report = Report::new("Figure 3 — concurrent conditional-find latency (DES)");
    report.set_custom(
        ["nodes", "concurrency", "finds", "finds/s", "p50", "p95", "p99"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    for nodes in [32u32, 64, 128, 256] {
        let spec = SimSpec::paper_preset(nodes, cost.clone()).unwrap();
        let r = ClusterSim::new(spec).run();
        report.add_row(r.query_row());
    }
    report.print();
    println!("\npaper: similar latency across cluster sizes despite proportional concurrency — shape reproduced\n");

    if quick_mode() {
        return;
    }
    // Live cross-check: one cluster, concurrency sweep.
    let kernels = Kernels::load_or_fallback("artifacts");
    let cluster = Cluster::start(
        ClusterSpec::small(3, 2),
        |sid| Ok(Box::new(LocalDir::temp(&format!("f3-{sid}"))?)),
        kernels,
        Registry::new(),
    )
    .unwrap();
    let client = cluster.client();
    client.create_index(IndexSpec::single("ts")).unwrap();
    client.create_index(IndexSpec::single("node_id")).unwrap();
    let wl = WorkloadConfig {
        monitored_nodes: 128,
        metrics_per_doc: 20,
        days: 30.0 / 1440.0,
        query_jobs: 32,
        ..Default::default()
    };
    IngestDriver::new(OvisGenerator::new(wl.clone()), 1000, 4)
        .run(&client)
        .unwrap();
    let mut live = Report::new("Figure 3 cross-check — live cluster, concurrency sweep");
    live.set_custom(
        ["concurrency", "finds", "finds/s", "p50", "p95", "p99"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    for conc in [1usize, 2, 4, 8] {
        let rep = QueryDriver::new(generate_jobs(&wl), conc).run(&client).unwrap();
        assert_eq!(rep.count_mismatches, 0);
        live.add_row(vec![
            conc.to_string(),
            rep.queries.to_string(),
            format!("{:.1}", rep.queries_per_sec()),
            human_duration_ns(rep.latency.p50()),
            human_duration_ns(rep.latency.p95()),
            human_duration_ns(rep.latency.p99()),
        ]);
    }
    live.print();
    cluster.shutdown();
}
