//! Wire protocol between cluster roles.
//!
//! Live mode transports messages over in-process channels (each server
//! role is a thread with an event loop); the Gemini interconnect model
//! accounts bytes/hops for every send so reports include the traffic a
//! real deployment would put on the torus. Message *types* double as the
//! RPC schema: every request carries a reply sender.

use std::sync::mpsc;

use crate::config::WriteConcern;
use crate::mongo::aggregate::{AggPipeline, AggRow};
use crate::mongo::bson::Document;
use crate::mongo::query::{Filter, FindOptions};
use crate::mongo::sharding::chunk::ChunkMap;
use crate::mongo::sharding::config_server::{Migration, VersionCheck};
use crate::mongo::sharding::migration::MState;
use crate::mongo::storage::index::IndexSpec;
use crate::mongo::storage::{CheckpointStats, CollectionStats};
use crate::util::ids::ShardId;

/// Reply channel for an RPC.
pub type Reply<T> = mpsc::Sender<T>;

/// Errors that cross the wire.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    StaleVersion { current: u64 },
    UnknownCursor(u64),
    /// The cursor's pinned MVCC snapshot fell behind the shard's
    /// snapshot-retention window and its versions were reclaimed. The
    /// cursor is dead; the query is cleanly retryable with a fresh
    /// `find` (which pins the current epoch).
    SnapshotExpired { at: u64, floor: u64 },
    /// The write touched a key range with an in-flight chunk migration
    /// (the rid-cursor copy stream cannot see updates/deletes applied
    /// behind it). Cleanly retryable: the migration finishes or aborts
    /// in bounded time, after which the write proceeds normally.
    MigrationInFlight { range: (u64, u64) },
    /// The member that received the write is not the replica set's
    /// primary. Cleanly retryable — nothing was applied. Carries the
    /// member index of the leader it last heard from (the router's next
    /// target) and the rejecting member's term.
    NotPrimary { leader: Option<u32>, term: u64 },
    /// Every reachable member of the shard's replica set is gone (dead
    /// channels). Writes must NOT be blindly retried — the outcome of an
    /// in-flight write is ambiguous; reads may be retried or degraded
    /// per read preference.
    ShardUnavailable { shard: u32 },
    Server(String),
}

impl WireError {
    /// Whether a *fresh* request (new `find`, re-routed write) can
    /// cleanly retry after this error. `ShardUnavailable` is only
    /// read-retryable — see the variant docs.
    pub fn retryable(&self) -> bool {
        matches!(
            self,
            WireError::StaleVersion { .. }
                | WireError::SnapshotExpired { .. }
                | WireError::MigrationInFlight { .. }
                | WireError::NotPrimary { .. }
        )
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::StaleVersion { current } => {
                write!(f, "stale chunk map version (shard has {current})")
            }
            WireError::UnknownCursor(c) => write!(f, "unknown cursor {c}"),
            WireError::SnapshotExpired { at, floor } => write!(
                f,
                "snapshot at epoch {at} expired (reclaim floor {floor}); retry the query"
            ),
            WireError::MigrationInFlight { range } => write!(
                f,
                "write overlaps chunk range [{}, {}] with an in-flight migration; retry",
                range.0, range.1
            ),
            WireError::NotPrimary { leader, term } => match leader {
                Some(l) => write!(f, "not primary (term {term}; try member {l})"),
                None => write!(f, "not primary (term {term}; no known leader)"),
            },
            WireError::ShardUnavailable { shard } => {
                write!(f, "no reachable member of shard {shard}'s replica set")
            }
            WireError::Server(msg) => write!(f, "server error: {msg}"),
        }
    }
}

impl std::error::Error for WireError {}

/// Result of an insert batch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InsertReply {
    pub inserted: usize,
    /// Indices (into the request batch) the shard rejected because it
    /// does not own their chunk — the router re-routes these after a map
    /// refresh (`ordered=false` semantics: keep going, collect errors).
    pub wrong_owner: Vec<usize>,
}

/// Result of a shard-side count. Carries the chunk-map version the
/// shard served under so the router can insist on a version-uniform
/// scatter: during a migration's publish/delete instant the per-shard
/// counts are only mutually consistent when every shard answered under
/// the same map (see ARCHITECTURE.md §6.3).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CountReply {
    pub n: u64,
    /// Chunk-map version in force when the count was taken.
    pub version: u64,
}

/// Result of a shard-side filtered update.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct UpdateReply {
    /// Documents the filter matched on this shard.
    pub matched: u64,
    /// Documents whose bytes actually changed (a `$set` to the same
    /// value matches but does not modify).
    pub modified: u64,
}

/// Result of a shard-side filtered delete.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DeleteReply {
    /// Documents removed on this shard.
    pub deleted: u64,
}

/// Result of a shard-side aggregation leg. Exactly one of `rows`/`docs`
/// is populated: the partial push-down path ships one accumulator row
/// per group (O(groups) on the wire), the full-ship baseline ships every
/// matched document for a central fold at the router. Carries the
/// serving map version for the router's uniform-version retry, same as
/// [`CountReply`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AggregateReply {
    /// Per-group partial accumulator rows (`--agg-partial 1`).
    pub rows: Vec<AggRow>,
    /// Matched documents for the router's central fold (`--agg-partial 0`).
    pub docs: Vec<Document>,
    /// Chunk-map version in force when the shard folded.
    pub version: u64,
}

/// One find/getMore result batch.
#[derive(Clone, Debug, PartialEq)]
pub struct FindReply {
    pub docs: Vec<Document>,
    /// Present while the cursor has more batches.
    pub cursor: Option<u64>,
}

/// One batch of a streaming chunk migration (source side).
#[derive(Clone, Debug, PartialEq)]
pub struct MigrateBatchReply {
    /// Documents of the requested range, in record-id order.
    pub docs: Vec<Document>,
    /// Record id of the last document returned — the resume cursor for
    /// the next batch. `None` when this batch is empty.
    pub last: Option<u64>,
    /// True when the scan reached the end of the record store: nothing
    /// of the range exists past `last` at scan time (writes arriving
    /// later get higher record ids and need a further pass).
    pub done: bool,
}

/// Durable staging state a destination shard reports after recovery —
/// the input to the cluster's migration reconciliation pass.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StagedMigration {
    /// Key-position range being migrated (inclusive bounds).
    pub range: (u64, u64),
    /// Donor shard the staged documents came from.
    pub from: ShardId,
    /// Whether the durable commit marker was written (roll forward) or
    /// not (roll back).
    pub committed: bool,
    /// Staged data documents (meta records excluded).
    pub docs: u64,
}

/// Result of a migration source delete.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DeleteChunkReply {
    /// Documents removed from the range.
    pub removed: u64,
    /// The triggered compaction, when one was requested: moved-away
    /// data leaves the source's journal and checkpoint chain.
    pub compacted: Option<CheckpointStats>,
}

/// Shard statistics snapshot.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardStatsReply {
    /// Live stats of the sharded collection.
    pub collection: CollectionStats,
    /// Chunks this shard currently owns.
    pub chunks_owned: u32,
    /// Chunk-map version the shard has.
    pub map_version: u64,
    /// Journal bytes buffered for the next group commit.
    pub journal_bytes: u64,
    /// On-disk journal footprint (live segments) — the quantity the
    /// storage lifecycle bounds.
    pub journal_disk_bytes: u64,
    /// Checkpoint generation of the shard's engine.
    pub checkpoint_generation: u64,
    /// Delta generations on top of the shard's on-disk full snapshot
    /// (bounded by `StoreConfig::full_checkpoint_chain`).
    pub checkpoint_chain_len: u64,
    /// On-disk bytes of the shard's live delta chain.
    pub delta_disk_bytes: u64,
    /// Data documents currently staged by an in-flight migration
    /// (invisible to queries until published).
    pub staged_docs: u64,
}

/// A replica-set member's role, reported by [`ShardRequest::RoleInfo`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RoleReply {
    /// "primary" | "secondary" | "candidate".
    pub role: &'static str,
    /// Current term (persisted hard state).
    pub term: u64,
    /// `(term, index)` of the member's last oplog entry.
    pub last: (u64, u64),
    /// Highest oplog index known committed (majority-durable).
    pub commit: u64,
    /// Member index of the leader this member last heard from.
    pub leader: Option<u32>,
}

/// Requests handled by a shard server (`mongod`).
pub enum ShardRequest {
    /// Insert a routed sub-batch (`insertMany(ordered=false)` leg).
    InsertBatch {
        version: u64,
        docs: Vec<Document>,
        wc: WriteConcern,
        reply: Reply<Result<InsertReply, WireError>>,
    },
    /// Open a query; returns the first batch (+ cursor if more).
    Find {
        filter: Filter,
        opts: FindOptions,
        reply: Reply<Result<FindReply, WireError>>,
    },
    GetMore {
        cursor: u64,
        reply: Reply<Result<FindReply, WireError>>,
    },
    /// Count matching documents without returning them (the `count`
    /// command; spares the wire the result set). The reply carries the
    /// serving map version for the router's uniform-version retry.
    Count {
        filter: Filter,
        reply: Reply<Result<CountReply, WireError>>,
    },
    /// Execute an aggregation pipeline leg over a pinned snapshot.
    /// With `partial` the shard folds matches into per-group partial
    /// accumulators over raw bytes and ships the O(groups) table; without
    /// it the shard decodes and ships every matched document (the bench
    /// baseline). The reply carries the serving map version for the
    /// router's uniform-version retry.
    Aggregate {
        pipeline: AggPipeline,
        partial: bool,
        reply: Reply<Result<AggregateReply, WireError>>,
    },
    /// Filter-driven update (`$set`-style top-level field merge) of a
    /// routed leg. Runs on the event loop like inserts; shard-key
    /// fields are immutable (rejected server-side). One journal frame
    /// per batch, MVCC batch-atomic.
    Update {
        version: u64,
        filter: Filter,
        set: Document,
        wc: WriteConcern,
        reply: Reply<Result<UpdateReply, WireError>>,
    },
    /// Filter-driven delete of a routed leg; one journal frame per
    /// batch, MVCC batch-atomic.
    Delete {
        version: u64,
        filter: Filter,
        wc: WriteConcern,
        reply: Reply<Result<DeleteReply, WireError>>,
    },
    CreateIndex {
        spec: IndexSpec,
        reply: Reply<Result<(), WireError>>,
    },
    /// Config pushes a new chunk map after any metadata mutation.
    // lint: allow(no_reply, one-way push from the config server; acking every
    // map broadcast would serialize the config loop on the slowest shard)
    SetMap { map: ChunkMap },
    /// Migration source: copy (do not delete) one bounded batch of the
    /// range, resuming from the record-id cursor `after`. Each batch is
    /// one mailbox message, so ingest and queries interleave with the
    /// stream (invariant IM2 in `sharding::migration`).
    MigrateBatch {
        range: (u64, u64),
        after: Option<u64>,
        limit: usize,
        reply: Reply<Result<MigrateBatchReply, WireError>>,
    },
    /// Migration destination: stage one copied batch into the
    /// `__migration` collection through the group-committed
    /// `insert_many` path. Invisible to queries until published.
    StageChunk {
        range: (u64, u64),
        from: ShardId,
        docs: Vec<Document>,
        reply: Reply<Result<usize, WireError>>,
    },
    /// Migration destination: durably mark the staged range committed
    /// (one journal frame + sync) — the migration's roll-forward point.
    /// Replies with the staged data-document count.
    CommitStaged {
        reply: Reply<Result<u64, WireError>>,
    },
    /// Migration destination: publish the committed staging into the
    /// live collection (one atomic cross-collection move frame). The
    /// staging *meta* record survives (with a drained document count)
    /// so a crash after publish still recovers to the committed path;
    /// [`ShardRequest::ClearStaged`] removes it once the donor's copy
    /// is deleted. Idempotent: re-publishing a drained staging is a
    /// 0-document no-op.
    PublishStaged {
        reply: Reply<Result<u64, WireError>>,
    },
    /// Migration destination: drop the drained staging meta left by
    /// [`ShardRequest::PublishStaged`] — the migration's final step,
    /// after the donor's range delete. Idempotent.
    ClearStaged {
        reply: Reply<Result<(), WireError>>,
    },
    /// Migration destination: drop an *uncommitted* staged range (abort
    /// path; refuses to drop a committed staging). Replies with the
    /// number of staged documents discarded.
    AbortStaged {
        reply: Reply<Result<u64, WireError>>,
    },
    /// Migration source: delete documents of a committed-away range as
    /// one atomic frame; with `compact` the delete is followed by a
    /// triggered checkpoint so the moved-away data stops occupying the
    /// journal and delta chain.
    DeleteChunk {
        range: (u64, u64),
        compact: bool,
        reply: Reply<Result<DeleteChunkReply, WireError>>,
    },
    /// Report any durable staging left by a killed migration (startup
    /// reconciliation input).
    StagedState {
        reply: Reply<Option<StagedMigration>>,
    },
    Stats {
        reply: Reply<ShardStatsReply>,
    },
    /// Admin command: checkpoint the storage engine now (end-of-job
    /// persistence barrier, or operator-forced compaction). Replies with
    /// what the compaction did.
    Checkpoint {
        reply: Reply<Result<CheckpointStats, WireError>>,
    },
    /// Replication (leader → follower): an AppendEntries-style oplog
    /// batch. `entries` are `__oplog` documents ordered by
    /// `(term, index)`; an empty batch is the heartbeat. The follower
    /// checks `(prev_term, prev_index)` against its own log tail,
    /// applies matching entries through the atomic-frame path at its
    /// own MVCC epochs, and advances its commit index to `commit`.
    /// With `reset` the follower discards its state and re-applies the
    /// batch as the full log (divergent-suffix resync, invariant IR4).
    // lint: allow(no_reply, one-way mailbox message between event loops — a
    // blocking reply would deadlock two peers replicating to each other; the
    // follower acks with a ReplicationAck message instead)
    Replicate {
        term: u64,
        leader: u32,
        prev_term: u64,
        prev_index: u64,
        entries: Vec<Document>,
        commit: u64,
        reset: bool,
    },
    /// Replication (follower → leader): ack for a [`ShardRequest::Replicate`]
    /// batch. `success` means the follower's log now durably matches the
    /// leader's through `ack_index`; failure means the prev-check missed
    /// and the leader must resync this follower.
    // lint: allow(no_reply, one-way mailbox message between event loops — the
    // leader folds acks into its commit index on its own loop; see Replicate)
    ReplicationAck {
        member: u32,
        term: u64,
        ack_index: u64,
        success: bool,
    },
    /// Election (candidate → all): request a vote for `term`. The voter
    /// grants at most one vote per term, and only to candidates whose
    /// log (`last_term`, `last_index`) is at least as up-to-date as its
    /// own (the Raft election restriction, invariant IR2).
    // lint: allow(no_reply, one-way mailbox message between event loops — the
    // candidate collects VoteReply messages on its own loop; a blocking reply
    // would deadlock two simultaneous candidates)
    RequestVote {
        term: u64,
        candidate: u32,
        last_term: u64,
        last_index: u64,
    },
    /// Election (voter → candidate): the answer to [`ShardRequest::RequestVote`].
    // lint: allow(no_reply, one-way mailbox message between event loops — see
    // RequestVote)
    VoteReply {
        term: u64,
        from: u32,
        granted: bool,
    },
    /// Report this member's replica-set role (tests, router probes).
    RoleInfo {
        reply: Reply<RoleReply>,
    },
    // lint: allow(no_reply, shutdown is fire-and-forget; callers join the
    // server thread instead of waiting on a reply)
    Shutdown,
}

/// Requests handled by the config server.
pub enum ConfigRequest {
    GetMap {
        reply: Reply<ChunkMap>,
    },
    /// A shard reports a chunk past the split threshold.
    ReportSplit {
        seen_version: u64,
        chunk: usize,
        at: u64,
        reply: Reply<Result<VersionCheck, WireError>>,
    },
    /// Begin a chunk migration (balancer round; executed by the cluster
    /// coordinator so the config thread never blocks on shard RPCs —
    /// see `cluster::Cluster::run_balancer_round`).
    BeginMigration {
        chunk: usize,
        to: ShardId,
        reply: Reply<Result<Migration, WireError>>,
    },
    /// Flip the in-flight migration's ownership (M2): relocates the
    /// migrating chunk by range, bumps the version, pushes the new map.
    /// Returns the new map version.
    CommitMigration {
        reply: Reply<Result<u64, WireError>>,
    },
    /// Mark the in-flight migration's staged copy as published on the
    /// destination: sets the chunk map's handoff to `published`, bumps
    /// the version, pushes the new map. From this instant the donor's
    /// remaining copies of the range are orphans and readers must drop
    /// them (ARCHITECTURE.md §6.3). Returns the new map version.
    PublishMigration {
        reply: Reply<Result<u64, WireError>>,
    },
    /// Record a coordinator-observed state transition of the in-flight
    /// migration (surfaced in [`ConfigStatsReply::migration_state`]).
    AdvanceMigration {
        state: MState,
        reply: Reply<Result<(), WireError>>,
    },
    /// Clear the finished in-flight migration and count it.
    FinishMigration {
        reply: Reply<Result<u64, WireError>>,
    },
    /// Abort the in-flight migration — awaited by the coordinator. Rolls
    /// the owner map back when the flip already happened; replies with
    /// the aborted migration, `None` if nothing was in flight.
    AbortMigration {
        reply: Reply<Option<Migration>>,
    },
    Stats {
        reply: Reply<ConfigStatsReply>,
    },
    // lint: allow(no_reply, shutdown is fire-and-forget; callers join the
    // server thread instead of waiting on a reply)
    Shutdown,
}

/// Config server statistics.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ConfigStatsReply {
    pub version: u64,
    pub chunks: usize,
    pub oplog_len: u64,
    pub migrations_done: u64,
    /// Migrations the coordinator aborted (rolled back).
    pub migrations_aborted: u64,
    /// M-state of the in-flight migration, if one is running.
    pub migration_state: Option<MState>,
}

/// Wire-size estimate of a document batch (bytes a real deployment would
/// put on the interconnect).
pub fn batch_wire_bytes(docs: &[Document]) -> u64 {
    docs.iter().map(|d| d.encoded_len() as u64).sum::<u64>() + 16
}

/// Wire-size estimate of a find request.
pub fn find_wire_bytes(filter: &Filter) -> u64 {
    filter.encoded_len() as u64 + 32
}

/// Wire-size estimate of an aggregate request.
pub fn agg_wire_bytes(pipeline: &AggPipeline) -> u64 {
    pipeline.encoded_len() as u64 + 32
}

/// Wire-size estimate of an aggregate reply (partial rows + any
/// full-ship documents — whichever leg the reply used).
pub fn agg_reply_wire_bytes(reply: &AggregateReply) -> u64 {
    reply.rows.iter().map(|r| r.wire_bytes() as u64).sum::<u64>()
        + batch_wire_bytes(&reply.docs)
}

/// Typed sender for a shard's mailbox.
pub type ShardMailbox = mpsc::Sender<ShardRequest>;
/// Typed sender for the config server's mailbox.
pub type ConfigMailbox = mpsc::Sender<ConfigRequest>;

/// Synchronous RPC helper: send and await the single reply.
pub fn rpc<Req, T>(
    mailbox: &mpsc::Sender<Req>,
    build: impl FnOnce(Reply<T>) -> Req,
) -> Result<T, WireError> {
    let (tx, rx) = mpsc::channel();
    mailbox
        .send(build(tx))
        .map_err(|_| WireError::Server("peer mailbox closed".into()))?;
    rx.recv()
        .map_err(|_| WireError::Server("peer dropped reply".into()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rpc_round_trip() {
        enum Req {
            Echo { v: u32, reply: Reply<u32> },
        }
        let (tx, rx) = mpsc::channel::<Req>();
        let server = std::thread::spawn(move || {
            while let Ok(Req::Echo { v, reply }) = rx.recv() {
                let _ = reply.send(v * 2);
                if v == 0 {
                    break;
                }
            }
        });
        let got = rpc(&tx, |reply| Req::Echo { v: 21, reply }).unwrap();
        assert_eq!(got, 42);
        let _ = rpc(&tx, |reply| Req::Echo { v: 0, reply });
        server.join().unwrap();
    }

    #[test]
    fn rpc_detects_dead_peer() {
        let (tx, rx) = mpsc::channel::<ShardRequest>();
        drop(rx);
        let err = rpc(&tx, |reply| ShardRequest::GetMore { cursor: 0, reply }).unwrap_err();
        assert!(matches!(err, WireError::Server(_)));
    }

    #[test]
    fn wire_byte_estimates_scale_with_content() {
        let d = Document::new().set("ts", 1i64).set("node_id", 2i64);
        let small = batch_wire_bytes(&[d.clone()]);
        let big = batch_wire_bytes(&vec![d; 100]);
        assert!(big > small * 50);
        let f = Filter::range("ts", 0i64, 10i64);
        assert!(find_wire_bytes(&f) > 32);
    }
}
