//! Named metric registry shared across threads.
//!
//! Metric *names* used by the cluster roles are declared once, in
//! [`names`]; call sites reference the constants instead of repeating
//! string literals. `pallas-lint` (rule `metrics`) enforces this: a
//! bare string literal at a `counter`/`gauge`/`observe` call site under
//! `src/mongo/` fails tier-1, as does a catalog entry no call site
//! references, or a catalog that disagrees with the table in
//! docs/ARCHITECTURE.md §8.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::Histogram;
use crate::json::Value;

/// The declared metric-name catalog.
///
/// One constant per metric the cluster roles emit, plus [`CATALOG`],
/// the machine-readable table `pallas-lint` checks call sites and the
/// ARCHITECTURE.md §8 table against. Names are `role.metric` with the
/// role prefix naming the emitting component (`shard.*`, `router.*`,
/// `config.*`) or the cross-role coordinator (`cluster.*`).
#[allow(missing_docs)]
pub mod names {
    // -- shard server: request latency histograms ----------------------
    pub const SHARD_INSERT_BATCH_NS: &str = "shard.insert_batch_ns";
    pub const SHARD_FIND_NS: &str = "shard.find_ns";
    pub const SHARD_COUNT_NS: &str = "shard.count_ns";
    pub const SHARD_MIGRATE_BATCH_NS: &str = "shard.migrate_batch_ns";
    pub const SHARD_UPDATE_NS: &str = "shard.update_ns";
    pub const SHARD_DELETE_NS: &str = "shard.delete_ns";
    // -- shard server: ingest + storage lifecycle -----------------------
    pub const SHARD_GROUP_COMMITS: &str = "shard.group_commits";
    pub const SHARD_DOCS_INSERTED: &str = "shard.docs_inserted";
    pub const SHARD_DOCS_UPDATED: &str = "shard.docs_updated";
    pub const SHARD_DOCS_DELETED: &str = "shard.docs_deleted";
    pub const SHARD_STALE_VERSION: &str = "shard.stale_version";
    /// Filter-driven writes rejected with `MigrationInFlight` because a
    /// matched document sits in an active handoff range (the router
    /// retries once the migration settles).
    pub const SHARD_WRITE_CONFLICTS: &str = "shard.write_conflicts";
    /// Checkpoints this shard wrote. Incremented at THREE distinct
    /// trigger sites in `server/shard.rs`, deliberately: the admin
    /// `Checkpoint` command, the post-group-commit threshold hook
    /// (`maybe_compact`), and the post-migration source compaction
    /// (`delete_range` with `compact`). Each checkpoint goes through
    /// exactly one of those paths, so the counter is exact — the three
    /// sites are different *reasons*, not a double count.
    pub const SHARD_CHECKPOINTS: &str = "shard.checkpoints";
    pub const SHARD_REBASES: &str = "shard.rebases";
    pub const SHARD_DELTA_BYTES: &str = "shard.delta_bytes";
    pub const SHARD_SEGMENTS_TRUNCATED: &str = "shard.segments_truncated";
    pub const SHARD_JOURNAL_BYTES_TRUNCATED: &str = "shard.journal_bytes_truncated";
    pub const SHARD_CHECKPOINT_ERRORS: &str = "shard.checkpoint_errors";
    // -- shard server: splits -------------------------------------------
    pub const SHARD_SPLITS: &str = "shard.splits";
    pub const SHARD_SPLIT_STALE: &str = "shard.split_stale";
    // -- shard server: query planner + read path ------------------------
    pub const SHARD_PLAN_INDEX_SORT: &str = "shard.plan_index_sort";
    pub const SHARD_PLAN_COMPOUND: &str = "shard.plan_compound";
    pub const SHARD_PLAN_INTERSECT: &str = "shard.plan_intersect";
    pub const SHARD_PLAN_IN_POINTS: &str = "shard.plan_in_points";
    pub const SHARD_PLAN_TS_RANGE: &str = "shard.plan_ts_range";
    pub const SHARD_PLAN_NODE_RANGE: &str = "shard.plan_node_range";
    pub const SHARD_PLAN_FULL_SCAN: &str = "shard.plan_full_scan";
    pub const SHARD_FIND_KERNEL_PATH: &str = "shard.find_kernel_path";
    pub const SHARD_FIND_MATCHER_PATH: &str = "shard.find_matcher_path";
    pub const SHARD_FIND_CANDIDATES: &str = "shard.find_candidates";
    pub const SHARD_FIND_MATCHES: &str = "shard.find_matches";
    pub const SHARD_FIND_DECODES: &str = "shard.find_decodes";
    // -- shard server: aggregation push-down ----------------------------
    /// `Aggregate` request latency (both partial and full-ship modes).
    pub const SHARD_AGG_NS: &str = "shard.agg_ns";
    /// Matching documents an aggregation folded (partial mode) or
    /// shipped (full-ship mode).
    pub const SHARD_AGG_DOCS: &str = "shard.agg_docs";
    /// Partial accumulator rows returned — one per group this shard
    /// saw; the push-down win is `agg_docs >> agg_groups`.
    pub const SHARD_AGG_GROUPS: &str = "shard.agg_groups";
    /// Partial aggregations whose accumulate loop ran on the compiled
    /// stats kernel (the pipeline shape and every probed value passed
    /// the losslessness gate).
    pub const SHARD_AGG_KERNEL_PATH: &str = "shard.agg_kernel_path";
    /// Partial aggregations folded scalar-side (shape ineligible, or a
    /// record failed the kernel's exactness gate mid-scan).
    pub const SHARD_AGG_SCALAR_PATH: &str = "shard.agg_scalar_path";
    // -- shard server: MVCC snapshot reads ------------------------------
    /// Read requests (find/getMore/count) served against a pinned
    /// snapshot — i.e. every read; the counter exists so mixed-workload
    /// runs can ratio reads against `shard.group_commits`.
    pub const SHARD_SNAPSHOT_READS: &str = "shard.snapshot_reads";
    /// Snapshots currently pinned (open cursors + in-flight reads),
    /// sampled by the writer at every maintenance turn.
    pub const SHARD_SNAPSHOTS_OPEN: &str = "shard.snapshots_open";
    /// Epochs between the committed epoch and the reclamation floor —
    /// how far the oldest open snapshot holds garbage collection back.
    pub const SHARD_RECLAIM_LAG: &str = "shard.reclaim_lag";
    // -- shard server: migration data plane -----------------------------
    pub const SHARD_MIGRATION_DOCS_IN: &str = "shard.migration_docs_in";
    pub const SHARD_MIGRATION_DOCS_OUT: &str = "shard.migration_docs_out";
    pub const SHARD_MIGRATION_DOCS_PUBLISHED: &str = "shard.migration_docs_published";
    pub const SHARD_MIGRATION_ABORTS: &str = "shard.migration_aborts";
    /// Live documents a read skipped because the shard's fence marked
    /// them orphans of a published handoff (donor-side filtering).
    pub const SHARD_ORPHANS_FILTERED: &str = "shard.orphans_filtered";
    // -- shard server: replica set / oplog replication -------------------
    /// Oplog entries this member appended as primary (data + `__oplog`
    /// journaled as one atomic frame).
    pub const SHARD_OPLOG_APPENDS: &str = "shard.oplog_appends";
    /// Oplog entries this member applied as a secondary (tailed from
    /// the primary's `Replicate` batches).
    pub const SHARD_OPLOG_APPLIED: &str = "shard.oplog_applied";
    /// Elections this member started (became candidate after an
    /// election timeout).
    pub const SHARD_ELECTIONS: &str = "shard.elections";
    /// Current replication term (persisted hard state), as a gauge.
    pub const SHARD_TERM: &str = "shard.term";
    /// `Replicate` messages this member sent as primary (heartbeats and
    /// entry batches share the message).
    pub const SHARD_HEARTBEATS: &str = "shard.heartbeats";
    /// Full-log resyncs this member performed after its log diverged
    /// from the leader's (invariant IR4).
    pub const SHARD_RESYNCS: &str = "shard.resyncs";
    // -- router ---------------------------------------------------------
    pub const ROUTER_INSERT_MANY_NS: &str = "router.insert_many_ns";
    pub const ROUTER_FIND_NS: &str = "router.find_ns";
    pub const ROUTER_UPDATE_NS: &str = "router.update_ns";
    pub const ROUTER_DELETE_NS: &str = "router.delete_ns";
    pub const ROUTER_FLUSH_NS: &str = "router.flush_ns";
    pub const ROUTER_INGEST_FLUSHES: &str = "router.ingest_flushes";
    pub const ROUTER_INGEST_FLUSH_DOCS: &str = "router.ingest_flush_docs";
    pub const ROUTER_MAP_REFRESH: &str = "router.map_refresh";
    pub const ROUTER_STALE_RETRIES: &str = "router.stale_retries";
    /// Filter-driven writes re-scattered after a `MigrationInFlight`
    /// rejection (per blocked shard per pass).
    pub const ROUTER_WRITE_BLOCKED_RETRIES: &str = "router.write_blocked_retries";
    /// Filter-driven writes re-broadcast to *all* shards because the
    /// chunk-map version moved mid-retry: a migration may have made
    /// matching documents live on a shard that already applied, so its
    /// `done` flag is no longer trustworthy.
    pub const ROUTER_WRITE_RESCATTERS: &str = "router.write_rescatters";
    /// Count scatters repeated because the per-shard replies carried
    /// different chunk-map versions (version-uniform count retry).
    pub const ROUTER_COUNT_RETRIES: &str = "router.count_retries";
    /// Documents the router dropped from a find because its map marked
    /// them orphans of a published handoff on the sending shard.
    pub const ROUTER_ORPHANS_FILTERED: &str = "router.orphans_filtered";
    /// `aggregate` request latency end-to-end (scatter, merge,
    /// finalize), both modes.
    pub const ROUTER_AGG_NS: &str = "router.agg_ns";
    /// Partial accumulator rows received from shards — bounded by
    /// groups × shards regardless of how many documents matched.
    pub const ROUTER_AGG_PARTIAL_ROWS: &str = "router.agg_partial_rows";
    /// Matching documents shipped whole to the router (full-ship
    /// baseline mode; zero when push-down is on).
    pub const ROUTER_AGG_DOCS_SHIPPED: &str = "router.agg_docs_shipped";
    /// Estimated shard→router reply bytes for aggregations — the wire
    /// quantity `fig_aggregation` sweeps.
    pub const ROUTER_AGG_REPLY_BYTES: &str = "router.agg_reply_bytes";
    /// Aggregate scatters repeated because per-shard replies carried
    /// different chunk-map versions (version-uniform retry).
    pub const ROUTER_AGG_RETRIES: &str = "router.agg_retries";
    /// Writes re-targeted after a `NotPrimary` rejection (the router
    /// updates its primary hint and retries with jittered backoff).
    pub const ROUTER_NOT_PRIMARY_RETRIES: &str = "router.not_primary_retries";
    /// Requests that found every member channel of a shard dead and
    /// surfaced `ShardUnavailable` (or degraded per read preference).
    pub const ROUTER_SHARD_UNAVAILABLE: &str = "router.shard_unavailable";
    // -- config server --------------------------------------------------
    pub const CONFIG_GET_MAP: &str = "config.get_map";
    pub const CONFIG_REPORT_SPLIT: &str = "config.report_split";
    pub const CONFIG_SPLITS: &str = "config.splits";
    pub const CONFIG_MIGRATION_FLIPS: &str = "config.migration_flips";
    pub const CONFIG_MIGRATION_PUBLISHES: &str = "config.migration_publishes";
    pub const CONFIG_MIGRATIONS: &str = "config.migrations";
    pub const CONFIG_MIGRATION_ABORTS: &str = "config.migration_aborts";
    // -- cluster coordinator (balancer / migration driver) --------------
    pub const CLUSTER_MIGRATIONS_FAILED: &str = "cluster.migrations_failed";
    pub const CLUSTER_MIGRATION_BATCHES: &str = "cluster.migration_batches";
    pub const CLUSTER_MIGRATION_DOCS: &str = "cluster.migration_docs";
    pub const CLUSTER_MIGRATIONS_RECOVERED: &str = "cluster.migrations_recovered";
    pub const CLUSTER_MIGRATIONS_ROLLED_BACK: &str = "cluster.migrations_rolled_back";

    /// Every declared metric with its kind — the machine-readable
    /// catalog. `pallas-lint` checks (a) every call-site name resolves
    /// here, (b) every entry is referenced by some call site, and
    /// (c) the docs/ARCHITECTURE.md §8 table lists exactly these rows.
    pub const CATALOG: &[(&str, &str)] = &[
        (SHARD_INSERT_BATCH_NS, "histogram"),
        (SHARD_FIND_NS, "histogram"),
        (SHARD_COUNT_NS, "histogram"),
        (SHARD_MIGRATE_BATCH_NS, "histogram"),
        (SHARD_UPDATE_NS, "histogram"),
        (SHARD_DELETE_NS, "histogram"),
        (SHARD_GROUP_COMMITS, "counter"),
        (SHARD_DOCS_INSERTED, "counter"),
        (SHARD_DOCS_UPDATED, "counter"),
        (SHARD_DOCS_DELETED, "counter"),
        (SHARD_STALE_VERSION, "counter"),
        (SHARD_WRITE_CONFLICTS, "counter"),
        (SHARD_CHECKPOINTS, "counter"),
        (SHARD_REBASES, "counter"),
        (SHARD_DELTA_BYTES, "counter"),
        (SHARD_SEGMENTS_TRUNCATED, "counter"),
        (SHARD_JOURNAL_BYTES_TRUNCATED, "counter"),
        (SHARD_CHECKPOINT_ERRORS, "counter"),
        (SHARD_SPLITS, "counter"),
        (SHARD_SPLIT_STALE, "counter"),
        (SHARD_PLAN_INDEX_SORT, "counter"),
        (SHARD_PLAN_COMPOUND, "counter"),
        (SHARD_PLAN_INTERSECT, "counter"),
        (SHARD_PLAN_IN_POINTS, "counter"),
        (SHARD_PLAN_TS_RANGE, "counter"),
        (SHARD_PLAN_NODE_RANGE, "counter"),
        (SHARD_PLAN_FULL_SCAN, "counter"),
        (SHARD_FIND_KERNEL_PATH, "counter"),
        (SHARD_FIND_MATCHER_PATH, "counter"),
        (SHARD_FIND_CANDIDATES, "counter"),
        (SHARD_FIND_MATCHES, "counter"),
        (SHARD_FIND_DECODES, "counter"),
        (SHARD_AGG_NS, "histogram"),
        (SHARD_AGG_DOCS, "counter"),
        (SHARD_AGG_GROUPS, "counter"),
        (SHARD_AGG_KERNEL_PATH, "counter"),
        (SHARD_AGG_SCALAR_PATH, "counter"),
        (SHARD_SNAPSHOT_READS, "counter"),
        (SHARD_SNAPSHOTS_OPEN, "gauge"),
        (SHARD_RECLAIM_LAG, "gauge"),
        (SHARD_MIGRATION_DOCS_IN, "counter"),
        (SHARD_MIGRATION_DOCS_OUT, "counter"),
        (SHARD_MIGRATION_DOCS_PUBLISHED, "counter"),
        (SHARD_MIGRATION_ABORTS, "counter"),
        (SHARD_ORPHANS_FILTERED, "counter"),
        (SHARD_OPLOG_APPENDS, "counter"),
        (SHARD_OPLOG_APPLIED, "counter"),
        (SHARD_ELECTIONS, "counter"),
        (SHARD_TERM, "gauge"),
        (SHARD_HEARTBEATS, "counter"),
        (SHARD_RESYNCS, "counter"),
        (ROUTER_INSERT_MANY_NS, "histogram"),
        (ROUTER_FIND_NS, "histogram"),
        (ROUTER_UPDATE_NS, "histogram"),
        (ROUTER_DELETE_NS, "histogram"),
        (ROUTER_FLUSH_NS, "histogram"),
        (ROUTER_INGEST_FLUSHES, "counter"),
        (ROUTER_INGEST_FLUSH_DOCS, "counter"),
        (ROUTER_MAP_REFRESH, "counter"),
        (ROUTER_STALE_RETRIES, "counter"),
        (ROUTER_WRITE_BLOCKED_RETRIES, "counter"),
        (ROUTER_WRITE_RESCATTERS, "counter"),
        (ROUTER_COUNT_RETRIES, "counter"),
        (ROUTER_ORPHANS_FILTERED, "counter"),
        (ROUTER_AGG_NS, "histogram"),
        (ROUTER_AGG_PARTIAL_ROWS, "counter"),
        (ROUTER_AGG_DOCS_SHIPPED, "counter"),
        (ROUTER_AGG_REPLY_BYTES, "counter"),
        (ROUTER_AGG_RETRIES, "counter"),
        (ROUTER_NOT_PRIMARY_RETRIES, "counter"),
        (ROUTER_SHARD_UNAVAILABLE, "counter"),
        (CONFIG_GET_MAP, "counter"),
        (CONFIG_REPORT_SPLIT, "counter"),
        (CONFIG_SPLITS, "counter"),
        (CONFIG_MIGRATION_FLIPS, "counter"),
        (CONFIG_MIGRATION_PUBLISHES, "counter"),
        (CONFIG_MIGRATIONS, "counter"),
        (CONFIG_MIGRATION_ABORTS, "counter"),
        (CLUSTER_MIGRATIONS_FAILED, "counter"),
        (CLUSTER_MIGRATION_BATCHES, "counter"),
        (CLUSTER_MIGRATION_DOCS, "counter"),
        (CLUSTER_MIGRATIONS_RECOVERED, "counter"),
        (CLUSTER_MIGRATIONS_ROLLED_BACK, "counter"),
    ];
}

/// Monotonic counter.
#[derive(Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous gauge.
#[derive(Clone, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A registry of named counters/gauges/histograms.
///
/// Cloning a registry shares the underlying metrics (it's an `Arc` of
/// maps); component constructors take a registry and register what they
/// need up front.
#[derive(Clone, Default)]
pub struct Registry {
    inner: Arc<Inner>,
}

#[derive(Default)]
struct Inner {
    counters: Mutex<BTreeMap<String, Counter>>,
    gauges: Mutex<BTreeMap<String, Gauge>>,
    histograms: Mutex<BTreeMap<String, Arc<Mutex<Histogram>>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Counter {
        self.inner
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        self.inner
            .gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Record one value into a named histogram.
    pub fn observe(&self, name: &str, value: u64) {
        let h = self
            .inner
            .histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Mutex::new(Histogram::new())))
            .clone();
        h.lock().unwrap().record(value);
    }

    /// Snapshot a histogram by name (empty if never observed).
    pub fn histogram(&self, name: &str) -> Histogram {
        self.inner
            .histograms
            .lock()
            .unwrap()
            .get(name)
            .map(|h| h.lock().unwrap().clone())
            .unwrap_or_default()
    }

    /// All counter values (snapshot).
    pub fn counter_values(&self) -> BTreeMap<String, u64> {
        self.inner
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.get()))
            .collect()
    }

    /// Serialize a full snapshot (counters, gauges, histogram summaries).
    pub fn snapshot_json(&self) -> Value {
        let mut counters = Value::object();
        for (k, v) in self.counter_values() {
            counters.set(&k, v);
        }
        let mut gauges = Value::object();
        for (k, v) in self.inner.gauges.lock().unwrap().iter() {
            gauges.set(k, v.get());
        }
        let mut hists = Value::object();
        for (k, h) in self.inner.histograms.lock().unwrap().iter() {
            let h = h.lock().unwrap();
            let mut o = Value::object();
            o.set("count", h.count())
                .set("mean", h.mean())
                .set("p50", h.p50())
                .set("p95", h.p95())
                .set("p99", h.p99())
                .set("max", h.max());
            hists.set(k, o);
        }
        let mut root = Value::object();
        root.set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", hists);
        root
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_names_unique_and_well_formed() {
        let mut seen = std::collections::BTreeSet::new();
        for (name, kind) in names::CATALOG {
            assert!(seen.insert(*name), "duplicate catalog entry {name}");
            assert!(
                matches!(*kind, "counter" | "gauge" | "histogram"),
                "bad kind {kind} for {name}"
            );
            let (role, metric) = name.split_once('.').expect("names are role.metric");
            assert!(matches!(role, "shard" | "router" | "config" | "cluster"));
            assert!(!metric.is_empty());
            assert!(
                name.chars().all(|c| c.is_ascii_lowercase() || c == '.' || c == '_'),
                "non-kebab name {name}"
            );
        }
    }

    #[test]
    fn counters_shared_by_name() {
        let r = Registry::new();
        let a = r.counter("ops");
        let b = r.counter("ops");
        a.add(2);
        b.inc();
        assert_eq!(r.counter("ops").get(), 3);
    }

    #[test]
    fn gauges_set_and_add() {
        let r = Registry::new();
        r.gauge("depth").set(5);
        r.gauge("depth").add(-2);
        assert_eq!(r.gauge("depth").get(), 3);
    }

    #[test]
    fn histograms_observe_and_snapshot() {
        let r = Registry::new();
        for v in [10u64, 20, 30] {
            r.observe("lat", v);
        }
        let h = r.histogram("lat");
        assert_eq!(h.count(), 3);
        assert!(r.histogram("nonexistent").count() == 0);
    }

    #[test]
    fn cloned_registry_shares_metrics() {
        let r = Registry::new();
        let r2 = r.clone();
        r.counter("x").inc();
        assert_eq!(r2.counter("x").get(), 1);
    }

    #[test]
    fn snapshot_is_json_object() {
        let r = Registry::new();
        r.counter("a").inc();
        r.gauge("g").set(7);
        r.observe("h", 42);
        let v = r.snapshot_json();
        assert_eq!(v.at(&["counters", "a"]).unwrap().as_u64(), Some(1));
        assert_eq!(v.at(&["gauges", "g"]).unwrap().as_i64(), Some(7));
        assert_eq!(v.at(&["histograms", "h", "count"]).unwrap().as_u64(), Some(1));
    }

    #[test]
    fn threaded_counting() {
        let r = Registry::new();
        let mut handles = vec![];
        for _ in 0..4 {
            let c = r.counter("n");
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.counter("n").get(), 4000);
    }
}
