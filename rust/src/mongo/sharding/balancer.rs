//! Balancer policy: keep chunk counts — and byte footprints — even
//! across shards.
//!
//! MongoDB's balancer moves chunks from the most-loaded to the
//! least-loaded shard while the spread exceeds a threshold. Chunk
//! *count* is the base invariant (it is what the config server can see
//! cheaply), but counts alone are blind to skew in chunk sizes: a shard
//! holding few fat chunks can carry most of the cluster's bytes on the
//! shared filesystem, exactly the footprint an HPC job must bound. The
//! policy here is therefore **byte-aware**: fed per-shard byte loads
//! from `ShardStatsReply` (live document bytes plus the lifecycle's
//! on-disk journal/delta bytes), it keeps planning moves while the byte
//! spread exceeds its own threshold — without ever violating the
//! chunk-count invariant, so count- and byte-driven rounds cannot
//! oscillate against each other.
//!
//! The policy stays pure (a list of proposed moves); the cluster layer
//! executes the moves through the streaming migration protocol
//! (`sharding::migration`) one at a time.

use crate::util::ids::ShardId;

/// Policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BalancerPolicy {
    /// Start balancing when `max - min` chunk counts exceed this.
    pub threshold: u32,
    /// Byte-aware planning: keep moving chunks while the max–min spread
    /// of per-shard bytes exceeds this (0 disables the byte trigger and
    /// restores count-only planning).
    pub byte_threshold: u64,
    /// Max moves proposed per round (migrations serialize; keep rounds
    /// short).
    pub max_moves_per_round: usize,
}

impl Default for BalancerPolicy {
    fn default() -> Self {
        Self {
            threshold: 2,
            byte_threshold: 256 * 1024 * 1024,
            max_moves_per_round: 4,
        }
    }
}

/// Per-shard byte load the planner balances, derived from live shard
/// stats (chunk counts come from the owner table itself).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardLoad {
    /// Byte footprint: live document bytes plus on-disk journal and
    /// delta-chain bytes (what the shard occupies on the filesystem).
    pub bytes: u64,
}

/// A proposed move of one chunk.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProposedMove {
    pub chunk: usize,
    pub from: ShardId,
    pub to: ShardId,
}

/// Count-only planning (no byte information — e.g. unit tests and the
/// property harness). Equivalent to [`plan_moves_with_loads`] with
/// all-zero loads.
pub fn plan_moves(
    owners: &[ShardId],
    num_shards: usize,
    policy: BalancerPolicy,
) -> Vec<ProposedMove> {
    plan_moves_with_loads(owners, &vec![ShardLoad::default(); num_shards], policy)
}

/// Plan moves given the chunk→owner table and per-shard byte loads.
///
/// Greedy and deterministic: while the chunk-count spread exceeds
/// `policy.threshold`, move one chunk from the current max-count shard
/// to the current min-count shard (lowest-index chunk of the donor
/// first). Once counts are within threshold, the **byte trigger** takes
/// over: while the byte spread exceeds `policy.byte_threshold`, move
/// one chunk from the byte-heaviest shard to the byte-lightest,
/// estimating each donor chunk at `bytes / chunks` (the planner only
/// sees shard-level stats). Byte-driven moves are taken only when they
/// strictly shrink the byte spread *and* keep the count spread within
/// threshold — both guards are required for convergence: without them
/// count- and byte-rounds would undo each other forever.
pub fn plan_moves_with_loads(
    owners: &[ShardId],
    loads: &[ShardLoad],
    policy: BalancerPolicy,
) -> Vec<ProposedMove> {
    let num_shards = loads.len();
    if num_shards == 0 {
        return Vec::new();
    }
    let mut counts = vec![0i64; num_shards];
    for o in owners {
        counts[o.index()] += 1;
    }
    let mut bytes: Vec<i64> = loads.iter().map(|l| l.bytes as i64).collect();
    // Per-chunk byte estimate, fixed at plan time per donor.
    let est: Vec<i64> = (0..num_shards)
        .map(|s| if counts[s] > 0 { bytes[s] / counts[s] } else { 0 })
        .collect();
    // Donor chunk queue per shard (ascending chunk index).
    let mut chunks_of: Vec<Vec<usize>> = vec![Vec::new(); num_shards];
    for (idx, o) in owners.iter().enumerate() {
        chunks_of[o.index()].push(idx);
    }
    let mut moves = Vec::new();
    let mut moved: std::collections::BTreeSet<usize> = Default::default();
    while moves.len() < policy.max_moves_per_round {
        let (max_s, &max_c) = counts
            .iter()
            .enumerate()
            .max_by_key(|(i, c)| (**c, usize::MAX - i))
            // lint: allow(panic, counts has num_shards > 0 entries per the guard at entry)
            .unwrap();
        let (min_s, &min_c) = counts
            .iter()
            .enumerate()
            .min_by_key(|(i, c)| (**c, *i))
            // lint: allow(panic, counts has num_shards > 0 entries per the guard at entry)
            .unwrap();
        let (donor, recv) = if max_c - min_c > policy.threshold as i64 {
            (max_s, min_s)
        } else if policy.byte_threshold > 0 {
            let (bmax_s, &bmax) = bytes
                .iter()
                .enumerate()
                .max_by_key(|(i, b)| (**b, usize::MAX - i))
                // lint: allow(panic, bytes has num_shards > 0 entries per the guard at entry)
                .unwrap();
            let (bmin_s, &bmin) = bytes
                .iter()
                .enumerate()
                .min_by_key(|(i, b)| (**b, *i))
                // lint: allow(panic, bytes has num_shards > 0 entries per the guard at entry)
                .unwrap();
            let spread = bmax - bmin;
            // Strict progress: the move must shrink the byte spread ...
            if spread <= policy.byte_threshold as i64
                || est[bmax_s] == 0
                || est[bmax_s] >= spread
            {
                break;
            }
            // ... and must not break the chunk-count invariant.
            let mut after = counts.clone();
            after[bmax_s] -= 1;
            after[bmin_s] += 1;
            let spread_after =
                // lint: allow(panic, after is a clone of the non-empty counts vector)
                after.iter().max().unwrap() - after.iter().min().unwrap();
            if spread_after > policy.threshold as i64 {
                break;
            }
            (bmax_s, bmin_s)
        } else {
            break;
        };
        // First not-yet-moved chunk of the donor.
        let Some(&chunk) = chunks_of[donor].iter().find(|c| !moved.contains(c)) else {
            break;
        };
        moved.insert(chunk);
        counts[donor] -= 1;
        counts[recv] += 1;
        bytes[donor] -= est[donor];
        bytes[recv] += est[donor];
        moves.push(ProposedMove {
            chunk,
            from: ShardId(donor as u32),
            to: ShardId(recv as u32),
        });
    }
    moves
}

#[cfg(test)]
mod tests {
    use super::*;

    fn owners(counts: &[u32]) -> Vec<ShardId> {
        let mut v = Vec::new();
        for (s, &c) in counts.iter().enumerate() {
            for _ in 0..c {
                v.push(ShardId(s as u32));
            }
        }
        v
    }

    #[test]
    fn balanced_cluster_proposes_nothing() {
        let o = owners(&[3, 3, 3, 4]);
        assert!(plan_moves(&o, 4, BalancerPolicy::default()).is_empty());
    }

    #[test]
    fn skewed_cluster_moves_from_max_to_min() {
        let o = owners(&[8, 1, 3]);
        let moves = plan_moves(&o, 3, BalancerPolicy::default());
        assert!(!moves.is_empty());
        assert_eq!(moves[0].from, ShardId(0));
        assert_eq!(moves[0].to, ShardId(1));
        // Simulate and verify spread shrinks monotonically.
        let mut counts = [8i64, 1, 3];
        for m in &moves {
            counts[m.from.index()] -= 1;
            counts[m.to.index()] += 1;
        }
        let spread = counts.iter().max().unwrap() - counts.iter().min().unwrap();
        assert!(spread <= 8 - 1 - moves.len() as i64 + moves.len() as i64); // sanity
        assert!(spread < 7);
    }

    #[test]
    fn respects_move_cap() {
        let o = owners(&[20, 0]);
        let policy = BalancerPolicy {
            threshold: 2,
            max_moves_per_round: 3,
            ..Default::default()
        };
        let moves = plan_moves(&o, 2, policy);
        assert_eq!(moves.len(), 3);
        // Distinct chunks each time.
        let set: std::collections::BTreeSet<_> = moves.iter().map(|m| m.chunk).collect();
        assert_eq!(set.len(), 3);
    }

    #[test]
    fn empty_shard_receives_first() {
        let o = owners(&[4, 4, 0]);
        let moves = plan_moves(
            &o,
            3,
            BalancerPolicy { threshold: 1, max_moves_per_round: 8, ..Default::default() },
        );
        assert!(moves.iter().all(|m| m.to == ShardId(2)));
    }

    #[test]
    fn deterministic_plans() {
        let o = owners(&[9, 2, 5, 0]);
        let a = plan_moves(&o, 4, BalancerPolicy::default());
        let b = plan_moves(&o, 4, BalancerPolicy::default());
        assert_eq!(a, b);
    }

    #[test]
    fn no_shards_plans_nothing() {
        assert!(plan_moves(&[], 0, BalancerPolicy::default()).is_empty());
        assert!(plan_moves_with_loads(&[], &[], BalancerPolicy::default()).is_empty());
    }

    #[test]
    fn all_empty_shards_plan_nothing() {
        // Shards exist but own no chunks at all: nothing to move.
        assert!(plan_moves(&[], 4, BalancerPolicy::default()).is_empty());
    }

    #[test]
    fn spread_exactly_at_threshold_is_stable() {
        // threshold = 2 means "balance when spread EXCEEDS 2": a spread
        // of exactly 2 must propose nothing, and 3 must propose a move.
        let policy = BalancerPolicy { byte_threshold: 0, ..Default::default() };
        let at = owners(&[5, 3]);
        assert!(plan_moves(&at, 2, policy).is_empty());
        let over = owners(&[6, 3]);
        assert_eq!(plan_moves(&over, 2, policy).len(), 1);
    }

    #[test]
    fn donor_with_fewer_chunks_than_move_cap() {
        // The donor owns only 3 chunks but the cap allows 8 moves: the
        // plan must stop at the donor's supply (distinct chunks only),
        // never propose a chunk twice, and never invent chunks.
        let o = owners(&[3, 0]);
        let policy = BalancerPolicy {
            threshold: 0,
            byte_threshold: 0,
            max_moves_per_round: 8,
        };
        let moves = plan_moves(&o, 2, policy);
        assert!(moves.len() <= 3, "only 3 chunks exist, got {moves:?}");
        let set: std::collections::BTreeSet<_> = moves.iter().map(|m| m.chunk).collect();
        assert_eq!(set.len(), moves.len(), "duplicate chunk in {moves:?}");
        assert!(moves.iter().all(|m| m.chunk < 3));
    }

    #[test]
    fn byte_skew_triggers_moves_when_counts_are_even() {
        // Equal chunk counts, but shard 0 carries 10x the bytes: the
        // byte trigger must plan moves count-only planning would skip.
        let o = owners(&[4, 4]);
        let loads = [
            ShardLoad { bytes: 1_000_000 },
            ShardLoad { bytes: 100_000 },
        ];
        let policy = BalancerPolicy {
            threshold: 2,
            byte_threshold: 200_000,
            max_moves_per_round: 8,
        };
        assert!(plan_moves(&o, 2, policy).is_empty(), "count-only sees balance");
        let moves = plan_moves_with_loads(&o, &loads, policy);
        assert!(!moves.is_empty(), "byte spread must trigger moves");
        assert!(moves.iter().all(|m| m.from == ShardId(0) && m.to == ShardId(1)));
        // Applying the moves at the planner's own 250k/chunk estimate
        // must strictly shrink the byte spread (no oscillation).
        let mut b = [1_000_000i64, 100_000];
        for m in &moves {
            b[m.from.index()] -= 250_000;
            b[m.to.index()] += 250_000;
        }
        assert!((b[0] - b[1]).abs() < 900_000, "spread must shrink, got {b:?}");
    }

    #[test]
    fn byte_moves_never_violate_count_invariant() {
        // Shard 0 is byte-heavy but owns barely more chunks; byte moves
        // must stop before pushing the count spread past the threshold.
        let o = owners(&[3, 2]);
        let loads = [
            ShardLoad { bytes: 10_000_000 },
            ShardLoad { bytes: 0 },
        ];
        let policy = BalancerPolicy {
            threshold: 2,
            byte_threshold: 1,
            max_moves_per_round: 16,
        };
        let moves = plan_moves_with_loads(&o, &loads, policy);
        let mut counts = [3i64, 2];
        for m in &moves {
            counts[m.from.index()] -= 1;
            counts[m.to.index()] += 1;
        }
        assert!(
            (counts[0] - counts[1]).abs() <= 2 + 1,
            "byte moves broke the count invariant: {counts:?}"
        );
    }

    #[test]
    fn byte_trigger_converges_to_fixpoint() {
        // Repeated rounds over the same (re-estimated) loads must reach
        // an empty plan — the strict-progress guard forbids oscillation.
        let mut o = owners(&[4, 4, 4]);
        let mut bytes = [900_000u64, 90_000, 90_000];
        let policy = BalancerPolicy {
            threshold: 2,
            byte_threshold: 150_000,
            max_moves_per_round: 2,
        };
        for _ in 0..20 {
            let mut counts = [0u64; 3];
            for s in &o {
                counts[s.index()] += 1;
            }
            let loads: Vec<ShardLoad> = (0..3)
                .map(|s| ShardLoad { bytes: bytes[s] })
                .collect();
            let moves = plan_moves_with_loads(&o, &loads, policy);
            if moves.is_empty() {
                return; // converged
            }
            for m in moves {
                let est = bytes[m.from.index()] / counts[m.from.index()].max(1);
                bytes[m.from.index()] -= est;
                bytes[m.to.index()] += est;
                o[m.chunk] = m.to;
            }
        }
        panic!("byte-aware planning did not converge");
    }

    #[test]
    fn convergence_property() {
        use crate::testing::check;
        use crate::util::rng::Pcg32;
        check(
            "balancer-converges",
            &(|rng: &mut Pcg32| {
                let shards = 2 + rng.next_bounded(8) as usize;
                let counts: Vec<u32> = (0..shards).map(|_| rng.next_bounded(20)).collect();
                counts
            }),
            |counts| {
                let shards = counts.len();
                let mut o = owners(counts);
                let policy = BalancerPolicy {
                    threshold: 2,
                    byte_threshold: 0,
                    max_moves_per_round: 64,
                };
                // Apply rounds until fixpoint; must converge quickly.
                for _ in 0..50 {
                    let moves = plan_moves(&o, shards, policy);
                    if moves.is_empty() {
                        // Spread must now be within threshold.
                        let mut c = vec![0i64; shards];
                        for s in &o {
                            c[s.index()] += 1;
                        }
                        let spread = c.iter().max().unwrap() - c.iter().min().unwrap();
                        return if spread <= 2 + 1 {
                            Ok(())
                        } else {
                            Err(format!("converged with spread {spread}"))
                        };
                    }
                    // Execute moves by reassigning owners (chunk indices
                    // here index into `o`).
                    for m in moves {
                        o[m.chunk] = m.to;
                    }
                }
                Err("did not converge in 50 rounds".into())
            },
        );
    }
}
