//! Cray Gemini-like 3D-torus interconnect cost model.
//!
//! Blue Waters connects its XE/XK blades with Gemini routers in a 3D
//! torus (24x24x24 for the full system). We model: hosts placed on torus
//! coordinates, hop counts under wrap-around routing, and a transfer
//! cost `latency + hops·per_hop + bytes/bandwidth`. Live mode records
//! these as virtual costs in metrics; the DES charges them to virtual
//! time.

/// Torus geometry + link parameters.
#[derive(Clone, Copy, Debug)]
pub struct Torus {
    pub dims: (u32, u32, u32),
    /// Software + NIC injection latency per message.
    pub base_latency_ns: u64,
    /// Per-hop router traversal.
    pub per_hop_ns: u64,
    /// Link bandwidth in bytes/sec (Gemini: ~4.7 GB/s per direction;
    /// we use an effective achievable figure).
    pub bandwidth_bps: f64,
}

impl Default for Torus {
    fn default() -> Self {
        Self {
            dims: (8, 8, 8),
            base_latency_ns: 1_500,
            per_hop_ns: 105, // Gemini ~100ns/hop class
            bandwidth_bps: 3.0e9,
        }
    }
}

impl Torus {
    pub fn nodes(&self) -> u32 {
        self.dims.0 * self.dims.1 * self.dims.2
    }

    /// Coordinate of host `h` (row-major placement, matching how an
    /// allocation tends to get a compact block).
    pub fn coord(&self, host: u32) -> (u32, u32, u32) {
        let (dx, dy, _dz) = self.dims;
        let x = host % dx;
        let y = (host / dx) % dy;
        let z = host / (dx * dy);
        (x, y, z % self.dims.2)
    }

    fn axis_hops(a: u32, b: u32, dim: u32) -> u32 {
        let d = a.abs_diff(b);
        d.min(dim - d)
    }

    /// Torus hop count between two hosts.
    pub fn hops(&self, a: u32, b: u32) -> u32 {
        let ca = self.coord(a);
        let cb = self.coord(b);
        Self::axis_hops(ca.0, cb.0, self.dims.0)
            + Self::axis_hops(ca.1, cb.1, self.dims.1)
            + Self::axis_hops(ca.2, cb.2, self.dims.2)
    }

    /// Modeled transfer time for `bytes` from host `a` to host `b`.
    pub fn transfer_ns(&self, a: u32, b: u32, bytes: u64) -> u64 {
        if a == b {
            // Intra-node: memcpy-class, charge bandwidth only.
            return (bytes as f64 / (10.0 * self.bandwidth_bps) * 1e9) as u64;
        }
        let hops = self.hops(a, b) as u64;
        self.base_latency_ns + hops * self.per_hop_ns
            + (bytes as f64 / self.bandwidth_bps * 1e9) as u64
    }

    /// Mean hop count over random pairs (used to parameterize the DES
    /// without tracking exact placements for 256-node sweeps).
    pub fn mean_hops(&self) -> f64 {
        // For a torus, mean per-axis distance is ~dim/4.
        (self.dims.0 as f64 + self.dims.1 as f64 + self.dims.2 as f64) / 4.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coords_are_unique_and_in_range() {
        let t = Torus { dims: (4, 3, 2), ..Default::default() };
        let mut seen = std::collections::BTreeSet::new();
        for h in 0..t.nodes() {
            let c = t.coord(h);
            assert!(c.0 < 4 && c.1 < 3 && c.2 < 2);
            assert!(seen.insert(c), "duplicate coord {c:?}");
        }
    }

    #[test]
    fn hops_symmetric_and_zero_on_self() {
        let t = Torus::default();
        for (a, b) in [(0u32, 1u32), (0, 77), (5, 200), (13, 13)] {
            assert_eq!(t.hops(a, b), t.hops(b, a));
        }
        assert_eq!(t.hops(42, 42), 0);
    }

    #[test]
    fn torus_wraparound_shortens_paths() {
        let t = Torus { dims: (8, 1, 1), ..Default::default() };
        // Host 0 and host 7 are adjacent around the ring.
        assert_eq!(t.hops(0, 7), 1);
        assert_eq!(t.hops(0, 4), 4); // antipodal
    }

    #[test]
    fn triangle_inequality_on_axis() {
        let t = Torus::default();
        for (a, b, c) in [(0u32, 10u32, 20u32), (3, 100, 400)] {
            assert!(t.hops(a, c) <= t.hops(a, b) + t.hops(b, c));
        }
    }

    #[test]
    fn transfer_cost_components() {
        let t = Torus::default();
        let small = t.transfer_ns(0, 1, 64);
        let big = t.transfer_ns(0, 1, 1_000_000);
        assert!(small >= t.base_latency_ns);
        // 1 MB at 3 GB/s ≈ 333 µs dominates latency.
        assert!(big > 300_000 && big < 500_000, "big={big}");
        // Same-host transfers skip the latency term.
        assert!(t.transfer_ns(5, 5, 64) < t.base_latency_ns);
    }

    #[test]
    fn mean_hops_reasonable() {
        let t = Torus { dims: (24, 24, 24), ..Default::default() };
        assert!((t.mean_hops() - 18.0).abs() < 1e-9);
    }
}
