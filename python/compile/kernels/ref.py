"""Pure-jnp correctness oracles for the Pallas kernels.

These definitions are the *specification*: the Pallas kernels and the Rust
fallback (`rust/src/runtime/fallback.rs`) must agree with them bit-exactly
(integer kernels) / to float tolerance (stats kernel). The FNV-1a
constants and the chunk-boundary convention here are mirrored in Rust —
change them in lockstep or the cross-language integration test fails.
"""

import jax.numpy as jnp

# FNV-1a 32-bit parameters (http://www.isthe.com/chongo/tech/comp/fnv/).
# Plain Python ints: jnp array constants would be captured as consts by
# pallas kernels, which pallas_call rejects.
FNV_OFFSET = 2166136261
FNV_PRIME = 16777619


def fnv1a_u32_pair(node_id, ts_min):
    """FNV-1a over the 8 little-endian bytes of (node_id, ts_min).

    Both inputs are uint32 arrays; returns uint32 hashes of the same
    shape. Arithmetic wraps mod 2^32 (numpy/jnp uint semantics).
    """
    node_id = node_id.astype(jnp.uint32)
    ts_min = ts_min.astype(jnp.uint32)
    h = jnp.full(node_id.shape, FNV_OFFSET, dtype=jnp.uint32)
    for word in (node_id, ts_min):
        for shift in (0, 8, 16, 24):
            byte = (word >> shift) & 0xFF
            h = (h ^ byte) * jnp.uint32(FNV_PRIME)
    return h


def chunk_of_hash(hashes, boundaries):
    """Chunk index for each hash.

    ``boundaries[j]`` is the *inclusive upper bound* of chunk ``j`` on the
    uint32 hash ring, sorted ascending; the last real boundary is
    0xFFFFFFFF and unused tail slots are padded with 0xFFFFFFFF. The chunk
    index is the count of boundaries strictly below the hash — a
    data-parallel compare-and-count rather than a divergent binary search
    (the TPU-friendly formulation; see DESIGN.md §Hardware-Adaptation).
    """
    cmp = boundaries[None, :] < hashes[:, None]
    return jnp.sum(cmp, axis=1).astype(jnp.int32)


def route_ref(node_id, ts_min, boundaries, chunk_to_shard, num_shards):
    """Oracle for the shard_route kernel + L2 histogram.

    Returns (shard_of i32[B], counts i32[S], hashes u32[B]).
    """
    h = fnv1a_u32_pair(node_id, ts_min)
    chunk = chunk_of_hash(h, boundaries)
    shard_of = jnp.take(chunk_to_shard.astype(jnp.int32), chunk)
    one_hot = shard_of[:, None] == jnp.arange(num_shards, dtype=jnp.int32)[None, :]
    counts = jnp.sum(one_hot.astype(jnp.int32), axis=0)
    return shard_of, counts, h


def filter_ref(ts_min, node_id, ts_lo, ts_hi, node_bitmap):
    """Oracle for the filter_scan kernel.

    Predicate: ``ts_lo <= ts < ts_hi`` AND bit ``node_id`` set in
    ``node_bitmap`` (u32 words, little-endian bit order). ``ts_lo``/
    ``ts_hi`` are shape-(1,) uint32 arrays. Returns (mask i32[B],
    count i32[1]).
    """
    ts_min = ts_min.astype(jnp.uint32)
    node_id = node_id.astype(jnp.uint32)
    word = jnp.take(node_bitmap, (node_id >> jnp.uint32(5)).astype(jnp.int32))
    bit = (word >> (node_id & jnp.uint32(31))) & jnp.uint32(1)
    in_range = (ts_lo[0] <= ts_min) & (ts_min < ts_hi[0])
    mask = (in_range & (bit == jnp.uint32(1))).astype(jnp.int32)
    return mask, jnp.sum(mask, dtype=jnp.int32)[None]


def stats_ref(metrics):
    """Oracle for the batch_stats kernel.

    metrics: f32[B, M]. Returns (min f32[M], max f32[M], mean f32[M]).
    """
    return (
        jnp.min(metrics, axis=0),
        jnp.max(metrics, axis=0),
        jnp.mean(metrics, axis=0),
    )
