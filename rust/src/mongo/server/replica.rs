//! Replica-set role engine: oplog replication and Raft-inspired
//! elections for one shard member (docs/ARCHITECTURE.md §10).
//!
//! Each logical shard can run as a **replica set** of `--replicas`
//! members. Every member is a full [`ShardServer`] with its own engine
//! directory; this module adds the replication state machine on top:
//!
//! * **Oplog** — an ordinary engine collection ([`OPLOG`]) whose
//!   entries are ordered by `(term, index)`. A primary journals each
//!   client write *and* its oplog entry as one [`AtomicOp`] frame
//!   (`OP_MULTI`), so the entry and the op it describes are atomic
//!   under crash recovery: replay restores both or neither.
//! * **Role engine** — primary / secondary / candidate with terms,
//!   randomized election timeouts, and majority quorum. Hard state
//!   (`term`, `voted_for`) persists in [`RAFT_STATE`] through the same
//!   journal, and is synced before any vote or candidacy leaves this
//!   member — a restart rejoins with its term intact.
//! * **Log tailing** — secondaries apply `Replicate` batches through
//!   the engine's atomic-frame path at their own MVCC epochs; "entry
//!   present in the log" and "applied to the data collection" are the
//!   same fact by construction. Retransmission from the leader's
//!   `next[]` cursor doubles as catch-up tailing for a rejoined member.
//!
//! Invariants (asserted by the failover kill-window suite):
//!
//! * **IR1** — at most one primary per term: a vote is granted at most
//!   once per term and a candidate needs a majority.
//! * **IR2** — an elected primary holds every committed entry: votes
//!   are refused to candidates whose `(last_term, last_index)` lags the
//!   voter's (the Raft election restriction).
//! * **IR3** — an entry commits only when a majority has durably
//!   applied it in the leader's current term; committed entries are
//!   never undone, and `w:majority` replies release only at commit.
//! * **IR4** — a rejoining member whose log diverged (uncommitted
//!   suffix from a deposed primary) discards it via a full resync
//!   (`reset` replication) — no divergent write is ever double-applied.
//!
//! All replication traffic is **one-way mailbox messages** between
//! event loops (`Replicate`/`ReplicationAck`, `RequestVote`/
//! `VoteReply`); a blocking reply channel would deadlock two members
//! messaging each other, so acks are folded in on each member's own
//! loop turn.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::metrics::names;
use crate::mongo::bson::{Document, Value};
use crate::mongo::storage::{AtomicOp, RecordId};
use crate::mongo::wire::{
    DeleteReply, InsertReply, Reply, RoleReply, ShardRequest, UpdateReply, WireError,
};

use super::shard::{ShardServer, COLLECTION};

/// The oplog collection: one document per replicated op, ordered by
/// `(term, index)`. Journaled atomically with the data op it describes.
pub const OPLOG: &str = "__oplog";

/// Durable Raft hard state: a single document `{term, voted_for}`,
/// updated (journal + sync) before any vote or candidacy acts.
pub const RAFT_STATE: &str = "__raft";

/// Cap on entries per `Replicate` batch (resyncs ship the full log).
const MAX_REPLICATE_BATCH: usize = 512;

/// A member's role in its replica set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Primary,
    Secondary,
    Candidate,
}

/// Wiring for one replica-set member, passed to `ShardServer::new`.
pub struct ReplicaConfig {
    /// This member's index within the set (0-based).
    pub member: u32,
    /// Mailboxes of **every** member of this shard's set, self
    /// included at `peers[member]` (sends skip the self slot).
    pub peers: Vec<mpsc::Sender<ShardRequest>>,
    /// Base election timeout; actual deadlines are jittered to
    /// `[T, 2T)` so concurrent candidacies rarely collide.
    pub election_timeout_ms: u64,
    /// Primary heartbeat / replication fan-out interval.
    pub heartbeat_ms: u64,
    /// Seed this member as the term-1 primary — only honoured on a
    /// **fresh** member (no persisted term, empty oplog); a restarted
    /// member always rejoins as a secondary with its persisted term.
    pub bootstrap_primary: bool,
}

/// A client reply parked until its oplog entry commits (`w:majority`).
pub(super) enum PendingReply {
    Insert {
        reply: Reply<Result<InsertReply, WireError>>,
        value: InsertReply,
    },
    Update {
        reply: Reply<Result<UpdateReply, WireError>>,
        value: UpdateReply,
    },
    Delete {
        reply: Reply<Result<DeleteReply, WireError>>,
        value: DeleteReply,
    },
}

impl PendingReply {
    fn send_ok(self) {
        match self {
            PendingReply::Insert { reply, value } => {
                let _ = reply.send(Ok(value));
            }
            PendingReply::Update { reply, value } => {
                let _ = reply.send(Ok(value));
            }
            PendingReply::Delete { reply, value } => {
                let _ = reply.send(Ok(value));
            }
        }
    }

    pub(super) fn send_err(self, e: WireError) {
        match self {
            PendingReply::Insert { reply, .. } => {
                let _ = reply.send(Err(e));
            }
            PendingReply::Update { reply, .. } => {
                let _ = reply.send(Err(e));
            }
            PendingReply::Delete { reply, .. } => {
                let _ = reply.send(Err(e));
            }
        }
    }
}

/// Per-member replication state (`None` on an unreplicated shard —
/// every hook below is a no-op then, preserving single-member
/// behaviour exactly).
pub(super) struct ReplicaState {
    pub(super) member: u32,
    pub(super) peers: Vec<mpsc::Sender<ShardRequest>>,
    pub(super) role: Role,
    /// Current term (hard state, persisted in [`RAFT_STATE`]).
    pub(super) term: u64,
    /// Who this member voted for in `term` (hard state).
    pub(super) voted_for: Option<u32>,
    /// Last known leader (the `NotPrimary` redirect hint).
    pub(super) leader: Option<u32>,
    /// In-memory oplog cache, `log[i]` = entry with index `i + 1`;
    /// rebuilt from the durable [`OPLOG`] collection at startup.
    pub(super) log: Vec<Document>,
    /// Highest committed index (majority-replicated in current term).
    pub(super) commit: u64,
    /// Leader state: next index to send each member.
    pub(super) next: Vec<u64>,
    /// Leader state: highest index each member has durably acked.
    pub(super) match_idx: Vec<u64>,
    /// Candidate state: bitmask of members whose vote we hold.
    pub(super) votes_from: u64,
    /// `w:majority` replies parked until their `(term, index)` commits.
    pub(super) pending: Vec<(u64, u64, PendingReply)>,
    election_timeout: Duration,
    heartbeat: Duration,
    pub(super) election_deadline: Instant,
    pub(super) heartbeat_deadline: Instant,
    /// xorshift64 state for election-timeout jitter.
    rng: u64,
    /// Record id of the [`RAFT_STATE`] document (updates re-id it).
    raft_rid: Option<RecordId>,
}

impl ReplicaState {
    /// Term of the log entry at 1-based `index` (0 for the empty
    /// prefix or out-of-range probes).
    pub(super) fn term_at(&self, index: u64) -> u64 {
        if index == 0 || index > self.log.len() as u64 {
            return 0;
        }
        self.log[(index - 1) as usize]
            .get_i64("term")
            .unwrap_or(0)
            .max(0) as u64
    }

    fn next_rand(&mut self) -> u64 {
        let mut x = self.rng;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.rng = x;
        x
    }

    /// Re-arm the election timer to `now + [T, 2T)`.
    pub(super) fn reset_election_deadline(&mut self) {
        let base = (self.election_timeout.as_millis() as u64).max(1);
        let jitter = self.next_rand() % base;
        self.election_deadline = Instant::now() + Duration::from_millis(base + jitter);
    }
}

/// Per-process random seed for the jitter stream, distinct per member.
fn seed(member: u32) -> u64 {
    use std::collections::hash_map::RandomState;
    use std::hash::{BuildHasher, Hasher};
    let mut h = RandomState::new().build_hasher();
    h.write_u32(member);
    h.finish() | 1
}

fn server_err(e: anyhow::Error) -> WireError {
    WireError::Server(e.to_string())
}

/// The `docs`-style array field of an oplog entry, decoded to owned
/// documents (non-document elements are ignored — entries are built
/// by [`docs_value`], so they never occur).
fn doc_array(entry: &Document, field: &str) -> Vec<Document> {
    match entry.get(field) {
        Some(Value::Array(items)) => items
            .iter()
            .filter_map(|v| match v {
                Value::Doc(d) => Some(d.clone()),
                _ => None,
            })
            .collect(),
        _ => Vec::new(),
    }
}

/// Wrap a document batch as an oplog-entry array field.
pub(super) fn docs_value(docs: &[Document]) -> Value {
    Value::Array(docs.iter().cloned().map(Value::Doc).collect())
}

impl ShardServer {
    /// Initialise replication state from the engine's recovered
    /// contents: the [`RAFT_STATE`] hard state, the [`OPLOG`] cache,
    /// and (on a **fresh** bootstrap member only) the term-1 primary
    /// seed. Called once from `ShardServer::new`.
    pub(super) fn replica_init(&mut self, cfg: ReplicaConfig) {
        self.engine.create_collection(OPLOG);
        self.engine.create_collection(RAFT_STATE);
        let mut term = 0u64;
        let mut voted_for = None;
        let mut raft_rid = None;
        for (rid, d) in self.engine.scan(RAFT_STATE) {
            term = d.get_i64("term").unwrap_or(0).max(0) as u64;
            voted_for = match d.get_i64("voted_for") {
                Some(v) if v >= 0 => Some(v as u32),
                _ => None,
            };
            raft_rid = Some(rid);
        }
        let mut log: Vec<Document> = self.engine.scan(OPLOG).map(|(_, d)| d).collect();
        log.sort_by_key(|e| e.get_i64("index").unwrap_or(0));
        let fresh = term == 0 && voted_for.is_none() && log.is_empty();
        let n = cfg.peers.len();
        let mut r = ReplicaState {
            member: cfg.member,
            peers: cfg.peers,
            role: Role::Secondary,
            term,
            voted_for,
            leader: None,
            log,
            commit: 0,
            next: vec![1; n],
            match_idx: vec![0; n],
            votes_from: 0,
            pending: Vec::new(),
            election_timeout: Duration::from_millis(cfg.election_timeout_ms.max(1)),
            heartbeat: Duration::from_millis(cfg.heartbeat_ms.max(1)),
            election_deadline: Instant::now(),
            heartbeat_deadline: Instant::now(),
            rng: seed(cfg.member),
            raft_rid,
        };
        r.reset_election_deadline();
        self.metrics.gauge(names::SHARD_TERM).set(term as i64);
        self.replica = Some(r);
        if cfg.bootstrap_primary && fresh {
            // Fresh cluster: seed member 0 as the term-1 primary so the
            // set accepts writes without waiting out an election. A
            // restarted member never takes this path — it rejoins as a
            // secondary under its persisted term and catches up by
            // oplog tailing (or wins a real election).
            if let Some(r) = self.replica.as_mut() {
                r.term = 1;
                r.voted_for = Some(r.member);
            }
            if let Err(e) = self.persist_hard_state() {
                eprintln!("warn: {}: bootstrap hard-state persist failed: {e}", self.id);
            }
            self.become_primary();
        }
    }

    /// How long the event loop may block before a replication timer
    /// (heartbeat or election) needs service.
    pub(super) fn replica_poll(&self) -> Duration {
        match &self.replica {
            Some(r) => {
                let deadline = match r.role {
                    Role::Primary => r.heartbeat_deadline,
                    _ => r.election_deadline,
                };
                deadline
                    .saturating_duration_since(Instant::now())
                    .max(Duration::from_millis(1))
            }
            None => Duration::from_secs(3600),
        }
    }

    /// Service expired replication timers: a primary fans out its log
    /// (heartbeat + retransmission + catch-up in one message), a
    /// non-primary whose election timer expired starts a candidacy.
    pub(super) fn replica_tick(&mut self) {
        let now = Instant::now();
        let (is_primary, hb_due, el_due) = match &self.replica {
            Some(r) => (
                r.role == Role::Primary,
                now >= r.heartbeat_deadline,
                now >= r.election_deadline,
            ),
            None => return,
        };
        if is_primary {
            if hb_due {
                self.replicate_all();
                if let Some(r) = self.replica.as_mut() {
                    r.heartbeat_deadline = now + r.heartbeat;
                }
            }
        } else if el_due {
            self.start_election();
        }
    }

    /// The `NotPrimary` rejection this member hands a misdirected
    /// write, carrying its best leader hint.
    pub(super) fn not_primary(&self) -> WireError {
        match &self.replica {
            Some(r) => WireError::NotPrimary { leader: r.leader, term: r.term },
            None => WireError::NotPrimary { leader: None, term: 0 },
        }
    }

    /// True when this member must reject client writes (`replica` set
    /// and not primary).
    pub(super) fn rejects_writes(&self) -> bool {
        matches!(&self.replica, Some(r) if r.role != Role::Primary)
    }

    pub(super) fn role_reply(&self) -> RoleReply {
        match &self.replica {
            Some(r) => {
                let last_index = r.log.len() as u64;
                RoleReply {
                    role: match r.role {
                        Role::Primary => "primary",
                        Role::Secondary => "secondary",
                        Role::Candidate => "candidate",
                    },
                    term: r.term,
                    last: (r.term_at(last_index), last_index),
                    commit: r.commit,
                    leader: r.leader,
                }
            }
            // An unreplicated shard is its own primary in every sense
            // that matters to a router.
            None => RoleReply {
                role: "primary",
                term: 0,
                last: (0, 0),
                commit: 0,
                leader: None,
            },
        }
    }

    /// Park a `w:majority` reply until its `(term, index)` entry
    /// commits ([`Self::drain_pending`] resolves its fate).
    pub(super) fn park_reply(&mut self, slot: (u64, u64), reply: PendingReply) {
        match self.replica.as_mut() {
            Some(r) => r.pending.push((slot.0, slot.1, reply)),
            // Unreachable by construction (writes only park when the
            // append returned a slot, which requires a replica), but a
            // stranded client reply would be worse than a late error.
            None => reply.send_err(WireError::Server(
                "write concern majority requires a replica set".into(),
            )),
        }
    }

    fn peer_send(&self, member: u32, msg: ShardRequest) {
        if let Some(r) = &self.replica {
            if let Some(tx) = r.peers.get(member as usize) {
                let _ = tx.send(msg);
            }
        }
    }

    /// Durably persist `{term, voted_for}` — journal frame **and
    /// sync** — before the vote or candidacy it records can act. The
    /// update re-ids the record, so the fresh rid is tracked.
    fn persist_hard_state(&mut self) -> Result<(), WireError> {
        let Some(r) = self.replica.as_mut() else { return Ok(()) };
        let doc = Document::new()
            .set("term", r.term as i64)
            .set("voted_for", r.voted_for.map(|v| v as i64).unwrap_or(-1));
        let fresh = match r.raft_rid {
            Some(rid) => self
                .engine
                .update_many(RAFT_STATE, &[(rid, doc)])
                .map_err(server_err)?,
            None => self
                .engine
                .insert_many(RAFT_STATE, &[doc])
                .map_err(server_err)?,
        };
        r.raft_rid = fresh.first().copied().or(r.raft_rid);
        let term = r.term;
        self.engine.sync().map_err(server_err)?;
        self.metrics.gauge(names::SHARD_TERM).set(term as i64);
        Ok(())
    }

    /// Primary-side oplog append: the data leg (if any) and its oplog
    /// entry journal as **one** atomic frame, group-commit, then the
    /// entry fans out to the secondaries. Returns the entry's
    /// `(term, index)` — the slot a `w:majority` reply parks under.
    pub(super) fn primary_append(
        &mut self,
        data: Option<AtomicOp>,
        kind: &str,
        fields: Vec<(&str, Value)>,
    ) -> Result<(u64, u64), WireError> {
        let (term, index) = match self.replica.as_ref() {
            Some(r) => (r.term, r.log.len() as u64 + 1),
            None => return Err(WireError::Server("not a replica-set member".into())),
        };
        let mut entry = Document::new()
            .set("term", term as i64)
            .set("index", index as i64)
            .set("kind", kind);
        for (k, v) in fields {
            entry.put(k, v);
        }
        let oplog_leg = AtomicOp::Insert { coll: OPLOG.to_string(), docs: vec![entry.clone()] };
        let ops: Vec<AtomicOp> = match data {
            Some(d) => vec![d, oplog_leg],
            None => vec![oplog_leg],
        };
        self.engine.apply_atomic(&ops).map_err(server_err)?;
        self.engine.sync().map_err(server_err)?;
        self.metrics.counter(names::SHARD_GROUP_COMMITS).inc();
        self.metrics.counter(names::SHARD_OPLOG_APPENDS).inc();
        if let Some(r) = self.replica.as_mut() {
            r.log.push(entry);
        }
        self.replicate_all();
        Ok((term, index))
    }

    /// Fan the log out to every peer from its `next[]` cursor — one
    /// message serves as heartbeat, replication, retransmission, and
    /// catch-up tailing (the cursor only advances on ack).
    pub(super) fn replicate_all(&mut self) {
        let msgs: Vec<(u32, ShardRequest)> = {
            let Some(r) = &self.replica else { return };
            if r.role != Role::Primary {
                return;
            }
            (0..r.peers.len() as u32)
                .filter(|m| *m != r.member)
                .map(|m| {
                    let next = r.next[m as usize].max(1);
                    let prev_index = next - 1;
                    let from = prev_index as usize;
                    let entries: Vec<Document> = r
                        .log
                        .get(from..)
                        .unwrap_or(&[])
                        .iter()
                        .take(MAX_REPLICATE_BATCH)
                        .cloned()
                        .collect();
                    (
                        m,
                        ShardRequest::Replicate {
                            term: r.term,
                            leader: r.member,
                            prev_term: r.term_at(prev_index),
                            prev_index,
                            entries,
                            commit: r.commit,
                            reset: false,
                        },
                    )
                })
                .collect()
        };
        for (m, msg) in msgs {
            self.metrics.counter(names::SHARD_HEARTBEATS).inc();
            self.peer_send(m, msg);
        }
    }

    /// Adopt a higher term observed on any message: step down to
    /// secondary, clear the vote, persist. Parked `w:majority` replies
    /// stay parked — their fate resolves when the new leader's log
    /// reaches this member (kept entries drain at commit, overwritten
    /// ones fail on resync).
    fn adopt_term(&mut self, term: u64) {
        if let Some(r) = self.replica.as_mut() {
            r.term = term;
            r.voted_for = None;
            r.role = Role::Secondary;
            r.leader = None;
            r.votes_from = 0;
            r.reset_election_deadline();
        }
        if let Err(e) = self.persist_hard_state() {
            eprintln!("warn: {}: hard-state persist failed: {e}", self.id);
        }
    }

    /// Election timeout fired: start a candidacy in the next term.
    /// The incremented term persists (journal + sync) before any
    /// `RequestVote` leaves this member.
    fn start_election(&mut self) {
        {
            let Some(r) = self.replica.as_mut() else { return };
            r.term += 1;
            r.role = Role::Candidate;
            r.voted_for = Some(r.member);
            r.leader = None;
            r.votes_from = 1u64 << (r.member as u64 & 63);
            r.reset_election_deadline();
        }
        self.metrics.counter(names::SHARD_ELECTIONS).inc();
        if let Err(e) = self.persist_hard_state() {
            // Candidacy without a durable term could double-vote after
            // a restart; stay secondary and retry next timeout.
            eprintln!("warn: {}: election persist failed: {e}", self.id);
            if let Some(r) = self.replica.as_mut() {
                r.role = Role::Secondary;
            }
            return;
        }
        let (single, msgs) = {
            let Some(r) = &self.replica else { return };
            let last_index = r.log.len() as u64;
            let msgs: Vec<(u32, ShardRequest)> = (0..r.peers.len() as u32)
                .filter(|m| *m != r.member)
                .map(|m| {
                    (
                        m,
                        ShardRequest::RequestVote {
                            term: r.term,
                            candidate: r.member,
                            last_term: r.term_at(last_index),
                            last_index,
                        },
                    )
                })
                .collect();
            (r.peers.len() == 1, msgs)
        };
        if single {
            self.become_primary();
            return;
        }
        for (m, msg) in msgs {
            self.peer_send(m, msg);
        }
    }

    /// Majority secured: take the primary role. The no-op entry in the
    /// new term is what lets prior-term entries commit (IR3/Raft
    /// §5.4.2 — a leader never counts replicas of old-term entries
    /// directly).
    fn become_primary(&mut self) {
        {
            let Some(r) = self.replica.as_mut() else { return };
            r.role = Role::Primary;
            r.leader = Some(r.member);
            let next0 = r.log.len() as u64 + 1;
            r.next = vec![next0; r.peers.len()];
            r.match_idx = vec![0; r.peers.len()];
            r.heartbeat_deadline = Instant::now();
        }
        if let Err(e) = self.primary_append(None, "n", Vec::new()) {
            eprintln!("warn: {}: term no-op append failed: {e}", self.id);
        }
        if let Some(r) = self.replica.as_mut() {
            r.heartbeat_deadline = Instant::now() + r.heartbeat;
        }
    }

    /// Vote request from a candidate (IR1 + IR2: one grant per term,
    /// and only to candidates whose log is at least as up-to-date).
    /// The grant persists (journal + sync) before the reply leaves.
    pub(super) fn handle_request_vote(
        &mut self,
        term: u64,
        candidate: u32,
        last_term: u64,
        last_index: u64,
    ) {
        let member = match self.replica.as_ref() {
            Some(r) => r.member,
            None => return,
        };
        let our_term = match self.replica.as_ref() {
            Some(r) => r.term,
            None => return,
        };
        if term > our_term {
            self.adopt_term(term);
        }
        let mut granted = false;
        let reply_term = {
            let Some(r) = self.replica.as_mut() else { return };
            if term == r.term {
                let my_last_index = r.log.len() as u64;
                let my_last_term = r.term_at(my_last_index);
                let up_to_date = (last_term, last_index) >= (my_last_term, my_last_index);
                let free = r.voted_for.is_none() || r.voted_for == Some(candidate);
                if up_to_date && free {
                    r.voted_for = Some(candidate);
                    r.reset_election_deadline();
                    granted = true;
                }
            }
            r.term
        };
        if granted && self.persist_hard_state().is_err() {
            // Never grant a vote the disk could forget (a restart
            // would free this member to vote twice in one term).
            granted = false;
            if let Some(r) = self.replica.as_mut() {
                r.voted_for = None;
            }
        }
        self.peer_send(
            candidate,
            ShardRequest::VoteReply { term: reply_term, from: member, granted },
        );
    }

    /// A vote arrived; a majority promotes this candidate.
    pub(super) fn handle_vote_reply(&mut self, term: u64, from: u32, granted: bool) {
        let our_term = match self.replica.as_ref() {
            Some(r) => r.term,
            None => return,
        };
        if term > our_term {
            self.adopt_term(term);
            return;
        }
        {
            let Some(r) = self.replica.as_mut() else { return };
            if r.role != Role::Candidate || term != r.term || !granted {
                return;
            }
            let bit = 1u64 << (from as u64 & 63);
            if r.votes_from & bit != 0 {
                return;
            }
            r.votes_from |= bit;
            if (r.votes_from.count_ones() as usize) * 2 <= r.peers.len() {
                return;
            }
        }
        self.become_primary();
    }

    /// An oplog batch (or heartbeat, or full-log resync) from the
    /// member claiming leadership of `term`.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn handle_replicate(
        &mut self,
        term: u64,
        leader: u32,
        prev_term: u64,
        prev_index: u64,
        entries: Vec<Document>,
        commit: u64,
        reset: bool,
    ) {
        let (our_term, member) = match self.replica.as_ref() {
            Some(r) => (r.term, r.member),
            None => return,
        };
        if term < our_term {
            self.peer_send(
                leader,
                ShardRequest::ReplicationAck {
                    member,
                    term: our_term,
                    ack_index: 0,
                    success: false,
                },
            );
            return;
        }
        if term > our_term {
            self.adopt_term(term);
        }
        if let Some(r) = self.replica.as_mut() {
            // A current-term Replicate is proof of a live leader: even
            // a candidate steps back down (IR1 — it lost this term).
            r.role = Role::Secondary;
            r.leader = Some(leader);
            r.reset_election_deadline();
        }
        if reset {
            self.resync_wipe();
        }
        let prev_ok = match self.replica.as_ref() {
            Some(r) => {
                reset
                    || (prev_index <= r.log.len() as u64 && r.term_at(prev_index) == prev_term)
            }
            None => return,
        };
        if !prev_ok {
            self.peer_send(
                leader,
                ShardRequest::ReplicationAck { member, term, ack_index: 0, success: false },
            );
            return;
        }
        let base = if reset { 0 } else { prev_index };
        let mut applied = 0u64; // entries verified-or-applied past `base`
        let mut ok = true;
        for entry in &entries {
            let idx = entry.get_i64("index").unwrap_or(0).max(0) as u64;
            let eterm = entry.get_i64("term").unwrap_or(0).max(0) as u64;
            if idx != base + applied + 1 {
                ok = false; // gap or malformed batch — resync will fix
                break;
            }
            let (have, matches) = match self.replica.as_ref() {
                Some(r) => (idx <= r.log.len() as u64, r.term_at(idx) == eterm),
                None => return,
            };
            if have {
                if !matches {
                    // Divergent suffix (uncommitted entries from a
                    // deposed leader): nack so the leader resyncs us
                    // (IR4) — never overwrite in place.
                    ok = false;
                    break;
                }
                applied += 1; // dedupe: already durably applied
                continue;
            }
            match self.secondary_apply_entry(entry) {
                Ok(()) => {
                    if let Some(r) = self.replica.as_mut() {
                        r.log.push(entry.clone());
                    }
                    self.metrics.counter(names::SHARD_OPLOG_APPLIED).inc();
                    applied += 1;
                }
                Err(e) => {
                    eprintln!("warn: {}: oplog apply at index {idx} failed: {e}", self.id);
                    ok = false;
                    break;
                }
            }
        }
        // One group commit per batch: the ack below is a durability
        // promise, so nothing acks before the sync lands.
        if (applied > 0 || reset) && self.engine.sync().is_err() {
            ok = false;
        } else if applied > 0 {
            self.metrics.counter(names::SHARD_GROUP_COMMITS).inc();
        }
        let ack_index = base + applied;
        if let Some(r) = self.replica.as_mut() {
            let last = r.log.len() as u64;
            // Commit never exceeds the verified prefix (a longer local
            // log may still hold an unverified divergent suffix).
            r.commit = r.commit.max(commit.min(ack_index).min(last));
        }
        self.drain_pending();
        self.peer_send(
            leader,
            ShardRequest::ReplicationAck { member, term, ack_index, success: ok },
        );
    }

    /// A follower acked (or nacked) a `Replicate` batch.
    pub(super) fn handle_replication_ack(
        &mut self,
        member: u32,
        term: u64,
        ack_index: u64,
        success: bool,
    ) {
        let our_term = match self.replica.as_ref() {
            Some(r) => r.term,
            None => return,
        };
        if term > our_term {
            self.adopt_term(term);
            return;
        }
        if term < our_term || !matches!(&self.replica, Some(r) if r.role == Role::Primary) {
            return;
        }
        let m = member as usize;
        if success {
            if let Some(r) = self.replica.as_mut() {
                if let Some(mi) = r.match_idx.get_mut(m) {
                    *mi = (*mi).max(ack_index);
                }
                if let Some(nx) = r.next.get_mut(m) {
                    *nx = (*nx).max(ack_index + 1);
                }
            }
            self.advance_commit();
        } else {
            // Prev-check missed: this follower's log diverged from
            // ours. Ship the full log with `reset` — it wipes and
            // re-applies, discarding its divergent suffix (IR4).
            if let Some(r) = self.replica.as_mut() {
                if let Some(nx) = r.next.get_mut(m) {
                    *nx = 1;
                }
                if let Some(mi) = r.match_idx.get_mut(m) {
                    *mi = 0;
                }
            }
            let msg = match self.replica.as_ref() {
                Some(r) => ShardRequest::Replicate {
                    term: r.term,
                    leader: r.member,
                    prev_term: 0,
                    prev_index: 0,
                    entries: r.log.clone(),
                    commit: r.commit,
                    reset: true,
                },
                None => return,
            };
            self.metrics.counter(names::SHARD_HEARTBEATS).inc();
            self.peer_send(member, msg);
        }
    }

    /// Leader commit rule (IR3): an index commits once a majority of
    /// members (self included) holds it durably **and** its entry is
    /// from the current term; earlier-term entries commit transitively
    /// under it.
    fn advance_commit(&mut self) {
        {
            let Some(r) = self.replica.as_mut() else { return };
            if r.role != Role::Primary {
                return;
            }
            let n_members = r.peers.len();
            let last = r.log.len() as u64;
            let mut commit = r.commit;
            for idx in (r.commit + 1)..=last {
                if r.term_at(idx) != r.term {
                    continue;
                }
                let member = r.member;
                let holders = 1 + r
                    .match_idx
                    .iter()
                    .enumerate()
                    .filter(|(m, mi)| *m as u32 != member && **mi >= idx)
                    .count();
                if holders * 2 > n_members {
                    commit = idx;
                }
            }
            r.commit = commit;
        }
        self.drain_pending();
    }

    /// Resolve parked `w:majority` replies against the current log:
    /// a committed entry with its parked term releases `Ok`; an entry
    /// overwritten or dropped by a resync (the write was rolled back —
    /// it is gone cluster-wide, so a retry cannot double-apply) fails
    /// with `NotPrimary`; anything else keeps waiting.
    fn drain_pending(&mut self) {
        let err = self.not_primary();
        let Some(r) = self.replica.as_mut() else { return };
        if r.pending.is_empty() {
            return;
        }
        let pending = std::mem::take(&mut r.pending);
        let commit = r.commit;
        let mut keep = Vec::new();
        let mut acks = Vec::new();
        let mut fails = Vec::new();
        for (term, index, reply) in pending {
            let present = index >= 1 && index <= r.log.len() as u64;
            if present && r.term_at(index) == term {
                if index <= commit {
                    acks.push(reply);
                } else {
                    keep.push((term, index, reply));
                }
            } else if present {
                fails.push(reply); // overwritten by a resync
            } else {
                fails.push(reply); // log shrank past it (resync)
            }
        }
        r.pending = keep;
        for reply in acks {
            reply.send_ok();
        }
        for reply in fails {
            reply.send_err(err.clone());
        }
    }

    /// Full-log resync (IR4): wipe the data collection, the oplog, the
    /// position histogram, and the log cache; the caller then applies
    /// the leader's full log. Deliberately **not** one atomic frame —
    /// a crash mid-resync leaves a partial log that the next rejoin
    /// corrects with another reset (correct-by-retry; the member never
    /// acks, so nothing depends on the torn state).
    fn resync_wipe(&mut self) {
        self.metrics.counter(names::SHARD_RESYNCS).inc();
        let data = self.engine.record_ids(COLLECTION);
        if !data.is_empty() {
            if let Err(e) = self.engine.remove_many(COLLECTION, &data) {
                eprintln!("warn: {}: resync data wipe failed: {e:#}", self.id);
            }
        }
        let oplog = self.engine.record_ids(OPLOG);
        if !oplog.is_empty() {
            if let Err(e) = self.engine.remove_many(OPLOG, &oplog) {
                eprintln!("warn: {}: resync oplog wipe failed: {e:#}", self.id);
            }
        }
        self.positions.clear();
        if let Some(r) = self.replica.as_mut() {
            r.log.clear();
            r.commit = 0;
        }
    }

    /// Apply one tailed oplog entry through the engine's atomic-frame
    /// path at this member's own MVCC epoch. The entry itself rides in
    /// the same frame, so "entry in the log" ⇔ "op applied" holds
    /// across crashes. Updates and deletes resolve their record ids
    /// **content-addressed**: the entry carries the old document, and
    /// rids differ across members, so the local rid is found by
    /// byte-comparing stored records against the old doc's encoding.
    fn secondary_apply_entry(&mut self, entry: &Document) -> Result<(), WireError> {
        let kind = entry.get("kind").and_then(Value::as_str).unwrap_or("?").to_string();
        let oplog_leg = AtomicOp::Insert { coll: OPLOG.to_string(), docs: vec![entry.clone()] };
        match kind.as_str() {
            "n" => {
                self.engine.apply_atomic(&[oplog_leg]).map_err(server_err)?;
            }
            "i" => {
                let docs = doc_array(entry, "docs");
                let positions: Vec<u64> =
                    docs.iter().filter_map(|d| self.position_of(d)).collect();
                self.engine
                    .apply_atomic(&[
                        AtomicOp::Insert { coll: COLLECTION.to_string(), docs },
                        oplog_leg,
                    ])
                    .map_err(server_err)?;
                for pos in positions {
                    *self.positions.entry(pos).or_insert(0) += 1;
                }
            }
            "u" => {
                let pairs = doc_array(entry, "pairs");
                let mut olds = Vec::with_capacity(pairs.len());
                let mut news = Vec::with_capacity(pairs.len());
                for p in &pairs {
                    match (p.get("old"), p.get("new")) {
                        (Some(Value::Doc(o)), Some(Value::Doc(n))) => {
                            olds.push(o.clone());
                            news.push(n.clone());
                        }
                        _ => {
                            return Err(WireError::Server(
                                "malformed update oplog entry".into(),
                            ))
                        }
                    }
                }
                let rids = self.resolve_rids(&olds)?;
                let updates: Vec<(RecordId, Document)> = rids.into_iter().zip(news).collect();
                self.engine
                    .apply_atomic(&[
                        AtomicOp::Update { coll: COLLECTION.to_string(), updates },
                        oplog_leg,
                    ])
                    .map_err(server_err)?;
                // Shard-key fields are immutable under update, so the
                // position histogram is unchanged.
            }
            "d" => {
                let olds = doc_array(entry, "olds");
                let rids = self.resolve_rids(&olds)?;
                self.engine
                    .apply_atomic(&[
                        AtomicOp::Remove { coll: COLLECTION.to_string(), rids },
                        oplog_leg,
                    ])
                    .map_err(server_err)?;
                for old in &olds {
                    if let Some(pos) = self.position_of(old) {
                        if let Some(c) = self.positions.get_mut(&pos) {
                            *c -= 1;
                            if *c == 0 {
                                self.positions.remove(&pos);
                            }
                        }
                    }
                }
            }
            k => {
                return Err(WireError::Server(format!("unknown oplog entry kind `{k}`")));
            }
        }
        Ok(())
    }

    /// Content-addressed rid resolution: find the local record whose
    /// stored bytes equal each old document's encoding. Duplicate
    /// documents map to *distinct* rids (first-match-wins per slot), so
    /// a batch deleting two identical docs resolves two records.
    fn resolve_rids(&self, olds: &[Document]) -> Result<Vec<RecordId>, WireError> {
        let encoded: Vec<Vec<u8>> = olds.iter().map(|d| d.encode()).collect();
        let mut out: Vec<Option<RecordId>> = vec![None; olds.len()];
        let mut remaining = olds.len();
        let reader = self.engine.reader();
        let view = reader.latest();
        for (rid, raw) in view.scan_raw_from(COLLECTION, None) {
            if remaining == 0 {
                break;
            }
            for (i, enc) in encoded.iter().enumerate() {
                if out[i].is_none() && enc.as_slice() == raw {
                    out[i] = Some(rid);
                    remaining -= 1;
                    break;
                }
            }
        }
        let rids: Vec<RecordId> = out.into_iter().flatten().collect();
        if rids.len() != olds.len() {
            return Err(WireError::Server(
                "oplog apply: old document not present on this member (log divergence)".into(),
            ));
        }
        Ok(rids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(term: i64, index: i64) -> Document {
        Document::new().set("term", term).set("index", index).set("kind", "n")
    }

    #[test]
    fn docs_value_round_trips_through_doc_array() {
        let docs = vec![
            Document::new().set("ts", 1i64).set("node_id", 2i64),
            Document::new().set("ts", 3i64).set("load", 0.5),
        ];
        let e = Document::new().set("kind", "i").set("docs", docs_value(&docs));
        assert_eq!(doc_array(&e, "docs"), docs);
        assert!(doc_array(&e, "missing").is_empty());
    }

    #[test]
    fn seed_is_nonzero_and_member_distinct_stream() {
        // xorshift64 requires a nonzero seed; `| 1` guarantees it.
        assert_ne!(seed(0), 0);
        assert_ne!(seed(7), 0);
    }

    #[test]
    fn term_at_reads_the_one_based_log() {
        let r = ReplicaState {
            member: 0,
            peers: Vec::new(),
            role: Role::Secondary,
            term: 3,
            voted_for: None,
            leader: None,
            log: vec![entry(1, 1), entry(1, 2), entry(3, 3)],
            commit: 0,
            next: Vec::new(),
            match_idx: Vec::new(),
            votes_from: 0,
            pending: Vec::new(),
            election_timeout: Duration::from_millis(150),
            heartbeat: Duration::from_millis(50),
            election_deadline: Instant::now(),
            heartbeat_deadline: Instant::now(),
            rng: seed(0),
            raft_rid: None,
        };
        assert_eq!(r.term_at(0), 0); // empty prefix
        assert_eq!(r.term_at(1), 1);
        assert_eq!(r.term_at(3), 3);
        assert_eq!(r.term_at(4), 0); // out of range
    }

    #[test]
    fn election_jitter_stays_in_one_to_two_timeouts() {
        let mut r = ReplicaState {
            member: 1,
            peers: Vec::new(),
            role: Role::Secondary,
            term: 0,
            voted_for: None,
            leader: None,
            log: Vec::new(),
            commit: 0,
            next: Vec::new(),
            match_idx: Vec::new(),
            votes_from: 0,
            pending: Vec::new(),
            election_timeout: Duration::from_millis(100),
            heartbeat: Duration::from_millis(50),
            election_deadline: Instant::now(),
            heartbeat_deadline: Instant::now(),
            rng: seed(1),
            raft_rid: None,
        };
        for _ in 0..64 {
            let before = Instant::now();
            r.reset_election_deadline();
            let dt = r.election_deadline.saturating_duration_since(before);
            assert!(dt >= Duration::from_millis(100), "jitter below base: {dt:?}");
            assert!(dt < Duration::from_millis(201), "jitter above 2T: {dt:?}");
        }
    }
}
