//! Integration: the AOT HLO path and the pure-Rust fallback must agree
//! bit-exactly on random batches (the cross-language correctness seal:
//! python ref == pallas kernel (pytest) and pallas HLO == rust fallback
//! (here) ⇒ all four implementations agree).
//!
//! Requires `make artifacts` to have run; tests are skipped (with a
//! loud message) if artifacts are absent so `cargo test` still works in
//! a fresh checkout.

use hpcstore::runtime::{fallback, Backend, Kernels};
use hpcstore::util::rng::Pcg32;

fn hlo_kernels() -> Option<Kernels> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        // The explicit `skipped:` prefix makes the no-op visible in CI
        // logs — a silently green HLO suite that never ran is the
        // failure mode this line exists to expose.
        println!("skipped: artifacts/manifest.json missing; run `make artifacts` to exercise the HLO path");
        return None;
    }
    let k = Kernels::load("artifacts").expect("loading artifacts");
    assert_eq!(k.backend(), Backend::Hlo);
    Some(k)
}

fn mk_chunk_table(rng: &mut Pcg32, chunks: usize, shards: usize) -> (Vec<u32>, Vec<i32>) {
    let mut bounds: Vec<u32> = (0..chunks - 1).map(|_| rng.next_u32()).collect();
    bounds.sort_unstable();
    bounds.dedup();
    bounds.push(u32::MAX);
    let c2s: Vec<i32> = (0..bounds.len())
        .map(|i| (i % shards) as i32)
        .collect();
    (bounds, c2s)
}

#[test]
fn route_hlo_equals_fallback() {
    let Some(k) = hlo_kernels() else { return };
    let mut rng = Pcg32::seeded(0xA0B1);
    for &(n_keys, chunks, shards) in
        &[(1usize, 1usize, 1usize), (100, 7, 7), (4096, 63, 63), (5000, 200, 63), (9000, 512, 64)]
    {
        let (bounds, c2s) = mk_chunk_table(&mut rng, chunks, shards);
        let node: Vec<u32> = (0..n_keys).map(|_| rng.next_bounded(30_000)).collect();
        let ts: Vec<u32> = (0..n_keys).map(|_| rng.next_u32()).collect();

        let hlo = k.route(&node, &ts, &bounds, &c2s, shards).unwrap();
        let (s_fb, c_fb, h_fb) = fallback::route_batch(&node, &ts, &bounds, &c2s, shards);

        assert_eq!(hlo.shard_of, s_fb, "shard_of mismatch at n={n_keys}");
        assert_eq!(hlo.counts, c_fb, "histogram mismatch at n={n_keys}");
        assert_eq!(hlo.hashes, h_fb, "hash mismatch at n={n_keys}");
    }
}

#[test]
fn filter_hlo_equals_fallback() {
    let Some(k) = hlo_kernels() else { return };
    let mut rng = Pcg32::seeded(0xF1F2);
    for &(n_docs, members) in &[(1usize, 0usize), (512, 40), (4096, 400), (6000, 1000)] {
        let bitmap = fallback::build_bitmap(
            (0..members).map(|_| rng.next_bounded(32_768)),
            1024,
        );
        let ts: Vec<u32> = (0..n_docs).map(|_| rng.next_bounded(2_000_000)).collect();
        let node: Vec<u32> = (0..n_docs).map(|_| rng.next_bounded(32_768)).collect();
        let lo = rng.next_bounded(1_000_000);
        let hi = lo + rng.next_bounded(1_000_000);

        let hlo = k.filter(&ts, &node, lo, hi, &bitmap).unwrap();
        let (m_fb, c_fb) = fallback::filter_batch(&ts, &node, lo, hi, &bitmap);

        assert_eq!(hlo.mask, m_fb, "mask mismatch at n={n_docs}");
        assert_eq!(hlo.count, c_fb, "count mismatch at n={n_docs}");
    }
}

#[test]
fn filter_pad_rows_never_leak() {
    // Node 0 a member + ts range covering 0: padding must still not
    // contribute to the count (pad ts = u32::MAX).
    let Some(k) = hlo_kernels() else { return };
    let bitmap = fallback::build_bitmap([0u32], 1024);
    let ts = vec![5u32; 10]; // 10 real docs, batch pads to 4096
    let node = vec![0u32; 10];
    let out = k.filter(&ts, &node, 0, u32::MAX, &bitmap).unwrap();
    assert_eq!(out.count, 10);
    assert_eq!(out.mask.len(), 10);
}

#[test]
fn route_pad_rows_never_leak() {
    let Some(k) = hlo_kernels() else { return };
    // 3 real keys in a 4096 batch; histogram must sum to 3.
    let (bounds, c2s) = (vec![1u32 << 30, u32::MAX], vec![0i32, 1]);
    let out = k.route(&[9, 8, 7], &[1, 2, 3], &bounds, &c2s, 2).unwrap();
    assert_eq!(out.counts.iter().sum::<i32>(), 3);
    assert_eq!(out.shard_of.len(), 3);
}

#[test]
fn stats_hlo_close_to_fallback() {
    let Some(k) = hlo_kernels() else { return };
    let m = k.shapes().stats_m;
    let mut rng = Pcg32::seeded(0x57A2);
    for &b in &[1usize, 100, 4096, 5000] {
        let metrics: Vec<f32> = (0..b * m)
            .map(|_| (rng.next_f64() * 1000.0 - 500.0) as f32)
            .collect();
        let hlo = k.stats(&metrics, b, m).unwrap();
        let (mn, mx, _) = fallback::stats_batch(&metrics, b, m);
        assert_eq!(hlo.min, mn, "min mismatch at b={b}");
        assert_eq!(hlo.max, mx, "max mismatch at b={b}");
        // Means: f32 reductions differ in association (kernel pairwise vs
        // scalar sequential) and the padded-batch correction amplifies
        // rounding, so compare against an f64 oracle with an absolute
        // tolerance derived from the summation error bound
        // (~log2(B)·eps·Σ|x| / B ≈ 3e-4 here; 0.02 is comfortably above).
        for col in 0..m {
            let oracle: f64 =
                (0..b).map(|r| metrics[r * m + col] as f64).sum::<f64>() / b as f64;
            let err = (hlo.mean[col] as f64 - oracle).abs();
            assert!(
                err < 2e-2,
                "mean mismatch at b={b} col={col}: {} vs {oracle} (err {err})",
                hlo.mean[col]
            );
        }
    }
}

#[test]
fn kernels_handle_is_cloneable_across_threads() {
    let Some(k) = hlo_kernels() else { return };
    let mut handles = vec![];
    for t in 0..4u32 {
        let k = k.clone();
        handles.push(std::thread::spawn(move || {
            let bounds = vec![u32::MAX];
            let c2s = vec![0i32];
            let node: Vec<u32> = (0..100).map(|i| i * t).collect();
            let ts: Vec<u32> = (0..100).collect();
            let out = k.route(&node, &ts, &bounds, &c2s, 1).unwrap();
            assert_eq!(out.counts, vec![100]);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}
