//! Live mini-Figure-2: ingest throughput as the shard count grows, on
//! real cluster threads (one machine, so absolute numbers are CPU-bound;
//! the paper-scale curve comes from `hpcstore sim` / the fig2 bench).
//!
//! ```sh
//! cargo run --release --example ingest_scaling
//! ```

use hpcstore::config::WorkloadConfig;
use hpcstore::metrics::Registry;
use hpcstore::mongo::cluster::{Cluster, ClusterSpec};
use hpcstore::mongo::storage::index::IndexSpec;
use hpcstore::mongo::storage::LocalDir;
use hpcstore::runtime::Kernels;
use hpcstore::util::fmt::markdown_table;
use hpcstore::workload::ovis::OvisGenerator;
use hpcstore::workload::IngestDriver;

fn main() -> anyhow::Result<()> {
    let kernels = Kernels::load_or_fallback("artifacts");
    println!("kernel backend: {:?}\n", kernels.backend());
    let mut rows = Vec::new();
    let mut base = None;
    for (shards, routers, pes) in [(1u32, 1u32, 2usize), (2, 2, 4), (4, 4, 8)] {
        let cluster = Cluster::start(
            ClusterSpec::small(shards, routers),
            move |sid| Ok(Box::new(LocalDir::temp(&format!("scale-{shards}-{sid}"))?)),
            kernels.clone(),
            Registry::new(),
        )?;
        let client = cluster.client();
        client.create_index(IndexSpec::single("ts")).map_err(anyhow::Error::msg)?;
        client.create_index(IndexSpec::single("node_id")).map_err(anyhow::Error::msg)?;
        let gen = OvisGenerator::new(WorkloadConfig {
            monitored_nodes: 200,
            metrics_per_doc: 75,
            days: 10.0 / 1440.0,
            ..Default::default()
        });
        let report = IngestDriver::new(gen, 500, pes).run(&client)?;
        let b = *base.get_or_insert(report.docs_per_sec);
        rows.push(vec![
            shards.to_string(),
            routers.to_string(),
            pes.to_string(),
            report.docs.to_string(),
            format!("{:.0}", report.docs_per_sec),
            format!("{:.2}x", report.docs_per_sec / b),
        ]);
        println!("shards={shards}: {}", report.summary());
        cluster.shutdown();
    }
    println!("\n## Live ingest scaling (single machine — CPU-bound)\n");
    print!(
        "{}",
        markdown_table(&["shards", "routers", "client PEs", "docs", "docs/s", "speedup"], &rows)
    );
    Ok(())
}
