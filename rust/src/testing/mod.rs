//! Mini property-testing kit (proptest is not in the vendored set).
//!
//! A [`Gen`] produces random values from a [`Pcg32`]; [`check`] runs a
//! property over many generated cases with a deterministic seed sequence
//! and reports the first failing case (seed + debug value) so failures
//! reproduce exactly. Shrinking is intentionally simple: numeric
//! generators retry the property on smaller bisections of the failing
//! value where the caller opts in via [`check_shrink`].

use crate::util::rng::Pcg32;

/// Number of cases per property unless overridden.
pub const DEFAULT_CASES: u32 = 128;

/// A generator of random values.
pub trait Gen {
    type Output;
    fn generate(&self, rng: &mut Pcg32) -> Self::Output;
}

impl<T, F: Fn(&mut Pcg32) -> T> Gen for F {
    type Output = T;
    fn generate(&self, rng: &mut Pcg32) -> T {
        self(rng)
    }
}

/// Run `prop` on `cases` generated inputs; panic with the reproducing
/// seed on the first failure.
pub fn check_with<G: Gen>(
    name: &str,
    seed: u64,
    cases: u32,
    gen: &G,
    prop: impl Fn(&G::Output) -> Result<(), String>,
) where
    G::Output: std::fmt::Debug,
{
    for case in 0..cases {
        let case_seed = seed.wrapping_add(case as u64);
        let mut rng = Pcg32::seeded(case_seed);
        let value = gen.generate(&mut rng);
        if let Err(msg) = prop(&value) {
            panic!(
                "property `{name}` failed (case {case}, seed {case_seed}):\n  \
                 input: {value:?}\n  error: {msg}"
            );
        }
    }
}

/// [`check_with`] with default seed/case count.
pub fn check<G: Gen>(name: &str, gen: &G, prop: impl Fn(&G::Output) -> Result<(), String>)
where
    G::Output: std::fmt::Debug,
{
    check_with(name, 0xC0FFEE, DEFAULT_CASES, gen, prop);
}

/// Property over a `u64` size parameter with bisection shrinking: on
/// failure at `n`, retries at n/2, n/4, ... and reports the smallest
/// failing size.
pub fn check_shrink(
    name: &str,
    max: u64,
    cases: u32,
    prop: impl Fn(u64) -> Result<(), String>,
) {
    let mut rng = Pcg32::seeded(0x5EED);
    for case in 0..cases {
        let n = rng.next_u64() % (max + 1);
        if let Err(first) = prop(n) {
            // Shrink by bisection toward 0.
            let mut smallest = (n, first);
            let mut candidate = n / 2;
            while candidate < smallest.0 {
                match prop(candidate) {
                    Err(msg) => {
                        smallest = (candidate, msg);
                        candidate /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property `{name}` failed (case {case}); smallest failing n={}: {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Convenience generators.
pub mod gens {
    use super::*;

    /// Uniform u32 in [lo, hi).
    pub fn u32_range(lo: u32, hi: u32) -> impl Gen<Output = u32> {
        move |rng: &mut Pcg32| rng.range_u32(lo, hi)
    }

    /// Vector of length in [0, max_len] with elements from `elem`.
    pub fn vec_of<G: Gen>(elem: G, max_len: usize) -> impl Gen<Output = Vec<G::Output>> {
        move |rng: &mut Pcg32| {
            let len = rng.next_bounded(max_len as u32 + 1) as usize;
            (0..len).map(|_| elem.generate(rng)).collect()
        }
    }

    /// ASCII identifier-ish string.
    pub fn ident(max_len: usize) -> impl Gen<Output = String> {
        move |rng: &mut Pcg32| {
            let len = 1 + rng.next_bounded(max_len.max(1) as u32) as usize;
            (0..len)
                .map(|_| {
                    let c = rng.next_bounded(36);
                    if c < 26 {
                        (b'a' + c as u8) as char
                    } else {
                        (b'0' + (c - 26) as u8) as char
                    }
                })
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", &gens::u32_range(0, 1000), |&n| {
            if n as u64 + 1 == 1 + n as u64 {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_reports_seed() {
        check("always-fails", &gens::u32_range(0, 10), |_| Err("nope".into()));
    }

    #[test]
    fn shrinking_lands_in_failing_band() {
        // Fails for all n >= 64; bisection from any failing n must report
        // a smallest failing value in [64, 127].
        let result = std::panic::catch_unwind(|| {
            check_shrink("ge-64", 1 << 20, 64, |n| {
                if n >= 64 {
                    Err(format!("{n} too big"))
                } else {
                    Ok(())
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        let n: u64 = msg
            .split("smallest failing n=")
            .nth(1)
            .unwrap()
            .split(':')
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!((64..128).contains(&n), "shrunk to {n}");
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let g = gens::vec_of(gens::u32_range(5, 10), 7);
        let mut rng = Pcg32::seeded(1);
        for _ in 0..100 {
            let v = g.generate(&mut rng);
            assert!(v.len() <= 7);
            assert!(v.iter().all(|&x| (5..10).contains(&x)));
        }
    }

    #[test]
    fn ident_gen_is_alnum() {
        let g = gens::ident(8);
        let mut rng = Pcg32::seeded(2);
        for _ in 0..50 {
            let s = g.generate(&mut rng);
            assert!(!s.is_empty() && s.len() <= 8);
            assert!(s.chars().all(|c| c.is_ascii_alphanumeric()));
        }
    }
}
