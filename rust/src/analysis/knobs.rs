//! Rule: **knob coverage** (the ablation surface).
//!
//! Every `StoreConfig` field is an experiment knob: the paper's
//! ablations flip them from the command line, and EXPERIMENTS.md is
//! the operator's index of what can be flipped. A field without a CLI
//! flag can only be exercised by editing source; a flag without a docs
//! row is invisible. For every field of `StoreConfig` in
//! `rust/src/config/mod.rs` this rule requires:
//!
//! 1. a **CLI flag** — the flag name appears as a string literal in
//!    `rust/src/main.rs` (the `FlagSpec` declaration). By default the
//!    flag is the field name with `_` → `-`; fields whose flag is
//!    spelled differently (e.g. `journal` → `--no-journal`) carry a
//!    `// lint: knob(<flag>)` annotation naming it;
//! 2. a **docs row** — `--<flag>` appears in `docs/EXPERIMENTS.md`.

use super::lexer::TokKind;
use super::{SourceTree, Violation};

const RULE: &str = "knob-coverage";
const CONFIG: &str = "rust/src/config/mod.rs";
const MAIN: &str = "rust/src/main.rs";
const EXPERIMENTS: &str = "docs/EXPERIMENTS.md";

pub fn check(tree: &SourceTree) -> Vec<Violation> {
    let Some(cfg) = tree.lexed(CONFIG) else { return Vec::new() };
    let mut out = Vec::new();

    // Locate `struct StoreConfig { ... }` and collect depth-1 fields.
    let t = &cfg.tokens;
    let mut fields: Vec<(String, usize)> = Vec::new();
    let mut i = 0;
    while i + 2 < t.len() {
        if t[i].text == "struct"
            && t[i + 1].text == "StoreConfig"
            && t[i + 2].text == "{"
        {
            let mut j = i + 3;
            let (mut bdepth, mut pdepth) = (1i32, 0i32);
            while j < t.len() && bdepth > 0 {
                match t[j].text.as_str() {
                    "{" => bdepth += 1,
                    "}" => bdepth -= 1,
                    "(" | "[" | "<" => pdepth += 1,
                    ")" | "]" | ">" => pdepth -= 1,
                    _ if bdepth == 1
                        && pdepth == 0
                        && t[j].kind == TokKind::Ident
                        && t[j].text != "pub"
                        && t.get(j + 1).is_some_and(|c| c.text == ":") =>
                    {
                        fields.push((t[j].text.clone(), t[j].line));
                    }
                    _ => {}
                }
                j += 1;
            }
            break;
        }
        i += 1;
    }

    let main = tree.lexed(MAIN);
    let experiments = tree.content(EXPERIMENTS).unwrap_or("");
    for (field, line) in &fields {
        // Explicit flag alias via annotation, else `_` → `-`.
        let flag = cfg
            .comments
            .iter()
            .filter(|c| {
                c.line == *line
                    || (c.line < *line
                        && (c.line..*line).all(|l| cfg.is_comment_only(l)))
            })
            .find_map(|c| {
                let rest = c.text.split("lint: knob(").nth(1)?;
                rest.split(')').next().map(str::to_string)
            })
            .unwrap_or_else(|| field.replace('_', "-"));
        let in_cli = main.as_ref().is_some_and(|m| {
            m.tokens.iter().any(|tok| tok.kind == TokKind::Str && tok.text == flag)
        });
        if !in_cli {
            out.push(Violation {
                file: CONFIG.to_string(),
                line: *line,
                rule: RULE,
                message: format!(
                    "StoreConfig::{field} has no CLI flag \"{flag}\" in rust/src/main.rs (annotate `// lint: knob(<flag>)` if it is spelled differently)"
                ),
            });
        }
        if !experiments.contains(&format!("--{flag}")) {
            out.push(Violation {
                file: CONFIG.to_string(),
                line: *line,
                rule: RULE,
                message: format!(
                    "StoreConfig::{field} has no `--{flag}` knob row in docs/EXPERIMENTS.md"
                ),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const CONFIG_SRC: &str = "pub struct StoreConfig {\n    pub max_chunk_docs: u64,\n    // lint: knob(no-journal)\n    pub journal: bool,\n}\n";

    fn tree(main: &str, experiments: &str) -> SourceTree {
        let mut t = SourceTree::new();
        t.add("rust/src/config/mod.rs", CONFIG_SRC);
        t.add("rust/src/main.rs", main);
        t.add("docs/EXPERIMENTS.md", experiments);
        t
    }

    #[test]
    fn covered_fields_pass() {
        let t = tree(
            "fn cli() { f(\"max-chunk-docs\"); f(\"no-journal\"); }",
            "| `--max-chunk-docs` | split threshold |\n| `--no-journal` | disable WAL |\n",
        );
        assert!(check(&t).is_empty(), "{:?}", check(&t));
    }

    #[test]
    fn missing_flag_is_flagged_at_field_line() {
        let t = tree(
            "fn cli() { f(\"no-journal\"); }",
            "| `--max-chunk-docs` | x |\n| `--no-journal` | x |\n",
        );
        let v = check(&t);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("max-chunk-docs"));
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn missing_docs_row_is_flagged() {
        let t = tree(
            "fn cli() { f(\"max-chunk-docs\"); f(\"no-journal\"); }",
            "| `--max-chunk-docs` | x |\n",
        );
        let v = check(&t);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].message.contains("--no-journal"));
    }

    #[test]
    fn knob_annotation_renames_the_expected_flag() {
        // Without the annotation, `journal` would demand `--journal`.
        let t = tree(
            "fn cli() { f(\"max-chunk-docs\"); f(\"journal\"); }",
            "| `--max-chunk-docs` | x |\n| `--journal` | x |\n",
        );
        let v = check(&t);
        assert_eq!(v.len(), 2, "{v:?}");
        assert!(v.iter().all(|x| x.message.contains("no-journal")));
    }
}
