//! Streaming, crash-safe chunk migration.
//!
//! The pre-refactor balancer shipped a whole chunk as one
//! `Vec<Document>` through a single mailbox message: the donor's event
//! loop was occupied for the full extract, the destination installed
//! the copy in one giant write, and an abort silently orphaned whatever
//! the destination had already installed. This module replaces that
//! with a **cursor-style batch stream** over the donor's record store:
//! every step is one bounded mailbox message, so donor and recipient
//! keep serving ingest and queries *between* batches — the paper's
//! requirement that the cluster run concurrently with the data-science
//! workload extends to its own rebalancing.
//!
//! # Protocol (M-states)
//!
//! The coordinator (the cluster's balancer round) drives one migration
//! at a time through the states below; the config server records the
//! current state in its metadata (`ConfigStatsReply::migration_state`).
//! Destination-side data is staged in a dedicated engine collection
//! (`__migration`), invisible to queries, together with a meta record
//! `{lo, hi, from}` — so the *durable* part of the state machine lives
//! in the shard engines, the only persistent stores a queued job has.
//!
//! ```text
//! M1 Streaming   MigrateBatch(donor) -> StageChunk(dest), cursor = last
//!                rid seen; donor still owns the chunk and keeps serving
//! M2 Flipped     config flips the owner map (version bump + SetMap
//!                push); catch-up batches drain the writes that raced
//!                the flip (they have higher rids than the cursor)
//! M3 Committed   dest journals a commit marker into the staging
//!                collection and syncs: the roll-forward point
//! M4 Cleanup     dest publishes staging -> live (one atomic move_many
//!                frame; the staging meta survives); config marks the
//!                handoff *published* (version bump + SetMap push: the
//!                donor's copies of the range are orphans from this
//!                instant and every reader's fence drops them); donor
//!                deletes the range (one atomic remove_many frame) and
//!                compacts; dest retires the staging meta (ClearStaged)
//! done           config clears the migration + handoff, counts it
//! ```
//!
//! The M4 order is **publish before delete**. Between those two
//! instants both shards hold a copy of the range, and the chunk map's
//! published [`MigrationHandoff`](super::chunk::MigrationHandoff)
//! tells readers which copy to drop (the donor's). The pre-refactor
//! order (delete first) had the opposite — and unfixable — window:
//! after the donor's delete and before the destination's publish the
//! range was live *nowhere*, so a scatter `Count` at that instant
//! undercounted. That was the transient orphan-read window
//! ARCHITECTURE.md §6.3 used to document as a known gap.
//!
//! Abort (any failure before M3): the destination deletes the staged
//! range — awaited, not fire-and-forget — and the config server rolls
//! the owner map back if it was already flipped.
//!
//! # Invariants
//!
//! * **IM1 (exclusive visibility at rest)** — after any kill and
//!   recovery, every migrated document is live on exactly one shard:
//!   staging is invisible to queries, the commit marker is a single
//!   atomic journal frame, and [`recover`] rolls an uncommitted staging
//!   back (donor still has everything) or a committed one forward
//!   (source delete is idempotent, publish is an atomic move).
//! * **IM2 (bounded stall during the copy)** — while data streams (the
//!   overwhelming majority of a migration's wall time), the donor's
//!   event loop is never held for more than one `migration_batch_docs`
//!   scan: batches are separate mailbox messages, so ingest and finds
//!   interleave with the stream. The commit-point range delete and its
//!   compaction are deliberately *not* streamed — one atomic frame, so
//!   a kill can never half-delete the chunk (crash safety over latency
//!   at the single commit instant). Each stream phase is additionally
//!   pass-capped by the donor's record count, so sustained ingest
//!   chasing the scan's tail cannot hold the balancer round forever.
//! * **IM3 (immutable range)** — the config server refuses to split any
//!   chunk overlapping the in-flight migration range, and relocates the
//!   migrating chunk by *range* at flip time, so concurrent splits of
//!   other chunks cannot redirect the flip.
//! * **IM4 (storage hand-back)** — commit triggers a source compaction:
//!   the moved-away documents leave the donor's journal and delta chain
//!   instead of occupying the shared filesystem forever.
//!
//! The kill-window matrix for this protocol is exercised in
//! `rust/tests/crash_matrix.rs` and documented in
//! `docs/ARCHITECTURE.md`.

use anyhow::Result;

use crate::metrics::{names, Registry};
use crate::mongo::wire::{rpc, ConfigMailbox, ConfigRequest, ShardMailbox, ShardRequest};
use crate::util::ids::ShardId;

/// Name of the destination-side staging collection. One in-flight
/// migration at a time (config-server serialized), so one collection
/// suffices; its meta record pins the range and donor.
pub const STAGING_COLLECTION: &str = "__migration";

/// Migration state machine (see the module docs for the protocol).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum MState {
    /// M1: batches streaming donor → destination staging.
    Streaming,
    /// M2: owner map flipped; catch-up batches draining.
    Flipped,
    /// M3: destination wrote its durable commit marker — roll-forward
    /// only from here.
    Committed,
    /// M4: source delete + compaction and destination publish.
    Cleanup,
}

impl std::fmt::Display for MState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MState::Streaming => write!(f, "streaming"),
            MState::Flipped => write!(f, "flipped"),
            MState::Committed => write!(f, "committed"),
            MState::Cleanup => write!(f, "cleanup"),
        }
    }
}

/// What one executed migration did (cluster metrics, tests).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MigrationOutcome {
    /// Documents copied during M1 streaming.
    pub docs_streamed: u64,
    /// Documents copied by post-flip catch-up batches.
    pub docs_caught_up: u64,
    /// Batch messages the stream took (donor stall is bounded by one).
    pub batches: u64,
    /// Documents deleted from the source at commit.
    pub docs_deleted: u64,
    /// Documents published live on the destination.
    pub docs_published: u64,
    /// Bytes of journal the post-commit source compaction truncated.
    pub source_journal_truncated: u64,
}

/// Outcome of the startup reconciliation pass ([`recover`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RecoveredMigrations {
    /// Committed migrations a kill interrupted, finished forward.
    pub rolled_forward: u64,
    /// Uncommitted staged ranges dropped (the donor still has the data).
    pub rolled_back: u64,
    /// Documents published by the roll-forwards.
    pub docs_recovered: u64,
}

/// Drive one chunk migration end to end through the M-state protocol.
///
/// `batch_docs` bounds every stream message (and therefore the donor's
/// per-message stall — invariant IM2). Failures before the commit
/// marker abort cleanly: the staged range is deleted on the
/// destination (awaited) and the config server rolls back. Failures
/// after the marker leave the durable staging in place for the next
/// job's [`recover`] pass — the migration rolls forward, never half
/// applies.
pub fn execute(
    config: &ConfigMailbox,
    shards: &[ShardMailbox],
    chunk: usize,
    to: ShardId,
    batch_docs: usize,
    metrics: &Registry,
) -> Result<MigrationOutcome> {
    let batch_docs = batch_docs.max(1);
    let migration = rpc(config, |reply| ConfigRequest::BeginMigration { chunk, to, reply })
        .map_err(|e| anyhow::anyhow!("begin: {e}"))?
        .map_err(|e| anyhow::anyhow!("begin: {e}"))?;
    let range = migration.range;
    let from = migration.from;
    let donor = &shards[from.index()];
    let dest = &shards[to.index()];
    let mut out = MigrationOutcome::default();

    // Phases up to the commit marker can abort; afterwards the
    // migration may only roll forward.
    //
    // Both stream phases carry a pass cap derived from the donor's
    // live record count: every non-terminal batch advances the cursor
    // past at least `batch_docs` records, so `docs / batch_docs + 8`
    // batches provably cover every record that existed when the phase
    // started. Without the cap, sustained ingest landing on the donor
    // keeps growing the record store's tail and a scan chasing `done`
    // might never observe the end — with it, M1 hands any remainder to
    // catch-up, and catch-up (whose range writes are already rejected
    // post-flip) provably covers every flip-time record.
    let donor_batch_cap = |donor: &ShardMailbox| -> u64 {
        let docs = rpc(donor, |reply| ShardRequest::Stats { reply })
            .map(|s| s.collection.docs)
            .unwrap_or(0);
        docs / batch_docs as u64 + 8
    };
    let mut cursor: Option<u64> = None;
    let pre_commit: Result<()> = (|| {
        // M1 — stream the range in bounded batches. Writes landing on
        // the donor during the stream get higher rids and are picked up
        // by later batches (or by catch-up, if the cap fires first).
        let cap = donor_batch_cap(donor);
        stream_range(donor, dest, range, from, batch_docs, cap, &mut cursor, &mut out.batches, &mut out.docs_streamed)?;
        // M2 — flip ownership at the config server (map version bump +
        // SetMap push to every shard happens before the rpc replies, so
        // catch-up batches sent after this line observe the donor's
        // post-flip rejection of new writes in the range).
        rpc(config, |reply| ConfigRequest::CommitMigration { reply })
            .map_err(|e| anyhow::anyhow!("flip: {e}"))?
            .map_err(|e| anyhow::anyhow!("flip: {e}"))?;
        // Catch-up: drain writes that raced the flip.
        let cap = donor_batch_cap(donor);
        stream_range(donor, dest, range, from, batch_docs, cap, &mut cursor, &mut out.batches, &mut out.docs_caught_up)?;
        // An empty chunk (common on pre-split ranges) migrates as a
        // pure metadata flip: nothing was staged, so there is nothing
        // to commit, delete, or publish — and CommitStaged would
        // rightly refuse ("nothing staged").
        if out.docs_streamed + out.docs_caught_up == 0 {
            return Ok(());
        }
        // M3 — destination durably commits the staged range.
        rpc(dest, |reply| ShardRequest::CommitStaged { reply })
            .map_err(|e| anyhow::anyhow!("commit staged: {e}"))?
            .map_err(|e| anyhow::anyhow!("commit staged: {e}"))?;
        let _ = rpc(config, |reply| ConfigRequest::AdvanceMigration {
            state: MState::Committed,
            reply,
        });
        Ok(())
    })();
    if let Err(e) = pre_commit {
        // Await the destination cleanup (the old code fired and forgot,
        // orphaning the partial copy), then roll the config back — but
        // only roll the owner map back when the destination *confirmed*
        // the staged range was dropped. If it refused (the staging is
        // already durably committed: the failure raced the marker's
        // reply) or is unreachable, unflipping would let the donor
        // accept new writes into a range the next job's roll-forward
        // will delete — real data loss. Recording `Committed` first
        // makes the config abort keep the flip (roll-forward pending).
        match rpc(dest, |reply| ShardRequest::AbortStaged { reply }) {
            Ok(Ok(_)) => {}
            _ => {
                let _ = rpc(config, |reply| ConfigRequest::AdvanceMigration {
                    state: MState::Committed,
                    reply,
                });
            }
        }
        let _ = rpc(config, |reply| ConfigRequest::AbortMigration { reply });
        metrics.counter(names::CLUSTER_MIGRATIONS_FAILED).inc();
        return Err(e);
    }

    // M4 — roll forward: publish, mark the handoff published, source
    // delete + compaction, retire the staging meta. An rpc failure
    // anywhere here (a dying shard thread) leaves the committed
    // staging on disk; the next job's `recover` finishes the protocol.
    // An empty migration already moved with the flip alone.
    if out.docs_streamed + out.docs_caught_up == 0 {
        let _ = rpc(config, |reply| ConfigRequest::FinishMigration { reply });
        return Ok(out);
    }
    let cleanup: Result<()> = (|| {
        let _ = rpc(config, |reply| ConfigRequest::AdvanceMigration {
            state: MState::Cleanup,
            reply,
        });
        // Publish first: from here both shards hold the range, and the
        // published handoff (next step) tells readers to drop the
        // donor's copy. Deleting first would open an undercount window.
        out.docs_published = rpc(dest, |reply| ShardRequest::PublishStaged { reply })
            .map_err(|e| anyhow::anyhow!("publish: {e}"))?
            .map_err(|e| anyhow::anyhow!("publish: {e}"))?;
        // Mark the handoff published. The config pushes the new map to
        // every shard *before* replying, so the donor's mailbox orders
        // SetMap(published) ahead of the DeleteChunk below: the donor
        // filters its orphans before it deletes them, and no reader
        // ever sees the range double-counted or missing.
        rpc(config, |reply| ConfigRequest::PublishMigration { reply })
            .map_err(|e| anyhow::anyhow!("mark published: {e}"))?
            .map_err(|e| anyhow::anyhow!("mark published: {e}"))?;
        let del = rpc(donor, |reply| ShardRequest::DeleteChunk { range, compact: true, reply })
            .map_err(|e| anyhow::anyhow!("source delete: {e}"))?
            .map_err(|e| anyhow::anyhow!("source delete: {e}"))?;
        out.docs_deleted = del.removed;
        out.source_journal_truncated = del
            .compacted
            .as_ref()
            .map(|ck| ck.journal_bytes_truncated)
            .unwrap_or(0);
        // The donor's copy is gone: the staging meta (kept by publish
        // so a kill before this point rolls forward) can now retire.
        rpc(dest, |reply| ShardRequest::ClearStaged { reply })
            .map_err(|e| anyhow::anyhow!("clear staged: {e}"))?
            .map_err(|e| anyhow::anyhow!("clear staged: {e}"))?;
        Ok(())
    })();
    match cleanup {
        Ok(()) => {
            let _ = rpc(config, |reply| ConfigRequest::FinishMigration { reply });
            metrics.counter(names::CLUSTER_MIGRATION_BATCHES).add(out.batches);
            metrics
                .counter(names::CLUSTER_MIGRATION_DOCS)
                .add(out.docs_streamed + out.docs_caught_up);
            Ok(out)
        }
        Err(e) => {
            // Release the config lock without counting the migration as
            // done (a post-marker migration never unflips); the durable
            // staging rolls forward at the next job's `recover` pass.
            let _ = rpc(config, |reply| ConfigRequest::AbortMigration { reply });
            metrics.counter(names::CLUSTER_MIGRATIONS_FAILED).inc();
            Err(e)
        }
    }
}

/// One streaming pass: batches from the donor's resumable cursor into
/// the destination's staging collection, until the donor reports the
/// scan reached the end of its record store — or `max_batches`
/// messages have been sent (liveness under sustained ingest; see the
/// cap derivation in [`execute`]).
#[allow(clippy::too_many_arguments)]
fn stream_range(
    donor: &ShardMailbox,
    dest: &ShardMailbox,
    range: (u64, u64),
    from: ShardId,
    batch_docs: usize,
    max_batches: u64,
    cursor: &mut Option<u64>,
    batches: &mut u64,
    docs: &mut u64,
) -> Result<()> {
    let mut sent = 0u64;
    loop {
        let after = *cursor;
        let rep = rpc(donor, |reply| ShardRequest::MigrateBatch {
            range,
            after,
            limit: batch_docs,
            reply,
        })
        .map_err(|e| anyhow::anyhow!("stream: {e}"))?
        .map_err(|e| anyhow::anyhow!("stream: {e}"))?;
        if let Some(last) = rep.last {
            *cursor = Some(last);
        }
        if !rep.docs.is_empty() {
            let n = rep.docs.len() as u64;
            rpc(dest, |reply| ShardRequest::StageChunk {
                range,
                from,
                docs: rep.docs,
                reply,
            })
            .map_err(|e| anyhow::anyhow!("stage: {e}"))?
            .map_err(|e| anyhow::anyhow!("stage: {e}"))?;
            *batches += 1;
            *docs += n;
        }
        sent += 1;
        if rep.done || sent >= max_batches {
            return Ok(());
        }
    }
}

/// Startup reconciliation: finish whatever migration a kill
/// interrupted. Runs in `Cluster::start` after the shards recover,
/// before any client traffic. A committed staging rolls *forward*
/// (source range delete — idempotent — then publish); an uncommitted
/// one rolls *back* (staged range dropped; the donor never deleted).
/// Either way invariant IM1 holds: no document is lost or duplicated.
pub fn recover(shards: &[ShardMailbox], metrics: &Registry) -> Result<RecoveredMigrations> {
    let mut out = RecoveredMigrations::default();
    for (i, dest) in shards.iter().enumerate() {
        let Ok(Some(staged)) = rpc(dest, |reply| ShardRequest::StagedState { reply }) else {
            continue;
        };
        if staged.committed {
            // The commit marker is durable: the migration happened.
            // Finish the source delete (a no-op if it already ran) and
            // only then publish — publishing while the donor still
            // holds its copy would duplicate the whole range (IM1), so
            // a *failed* delete leaves the committed staging in place
            // for the next recovery attempt instead. A vanished source
            // shard (shrunk topology) cannot hold a conflicting copy,
            // so publishing is still exactly-once among live shards.
            if staged.from.index() != i {
                if let Some(src) = shards.get(staged.from.index()) {
                    rpc(src, |reply| ShardRequest::DeleteChunk {
                        range: staged.range,
                        compact: true,
                        reply,
                    })
                    .map_err(|e| anyhow::anyhow!("recover source delete: {e}"))?
                    .map_err(|e| anyhow::anyhow!("recover source delete: {e}"))?;
                }
            }
            let n = rpc(dest, |reply| ShardRequest::PublishStaged { reply })
                .map_err(|e| anyhow::anyhow!("recover publish: {e}"))?
                .map_err(|e| anyhow::anyhow!("recover publish: {e}"))?;
            // Recovery runs before any client traffic, so the
            // delete-then-publish order above is unobservable (no
            // reader exists to see the gap) and the live path's
            // publish-first fence is unnecessary. Publish keeps the
            // staging meta; retire it now that the source is clean.
            rpc(dest, |reply| ShardRequest::ClearStaged { reply })
                .map_err(|e| anyhow::anyhow!("recover clear staged: {e}"))?
                .map_err(|e| anyhow::anyhow!("recover clear staged: {e}"))?;
            out.rolled_forward += 1;
            out.docs_recovered += n;
            metrics.counter(names::CLUSTER_MIGRATIONS_RECOVERED).inc();
        } else {
            rpc(dest, |reply| ShardRequest::AbortStaged { reply })
                .map_err(|e| anyhow::anyhow!("recover abort: {e}"))?
                .map_err(|e| anyhow::anyhow!("recover abort: {e}"))?;
            out.rolled_back += 1;
            metrics.counter(names::CLUSTER_MIGRATIONS_ROLLED_BACK).inc();
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mstates_order_matches_protocol() {
        assert!(MState::Streaming < MState::Flipped);
        assert!(MState::Flipped < MState::Committed);
        assert!(MState::Committed < MState::Cleanup);
        assert_eq!(format!("{}", MState::Committed), "committed");
    }

    #[test]
    fn outcome_defaults_are_zero() {
        let o = MigrationOutcome::default();
        assert_eq!(o.docs_streamed + o.docs_caught_up + o.docs_published, 0);
        assert_eq!(RecoveredMigrations::default().rolled_forward, 0);
    }
}
