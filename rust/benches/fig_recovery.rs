//! FR — recovery time vs journal size, before and after checkpoint
//! compaction, plus the delta-chain-length axis.
//!
//! The lifecycle claim this bench measures: without compaction a killed
//! job replays its *entire* write history on the next deployment
//! (recovery is O(total writes)); with a checkpoint the next job loads
//! the snapshot and replays only the post-checkpoint tail. Rows sweep
//! the ingested volume; for each volume the same store is recovered
//! twice — once from the full journal, once after `checkpoint()` — and
//! the replayed frame/byte counts come from the engine's own
//! `RecoveryReport`.
//!
//! The second table sweeps **chain length**: the same base corpus plus
//! K incremental (delta) generations. Checkpoint cost per generation is
//! O(new writes) — the `delta bytes` column stays flat while the chain
//! grows — and recovery folds base + K deltas + the journal tail, so
//! the recovery-time column shows what a longer rebase threshold
//! (`StoreConfig::full_checkpoint_chain`) costs at re-deploy time.
//!
//! Run: `cargo bench --bench fig_recovery` (add `--quick` for a small
//! sweep). See `docs/EXPERIMENTS.md` for the recorded-results template.

use std::time::Instant;

use hpcstore::benchkit::{quick_mode, Report};
use hpcstore::mongo::bson::Document;
use hpcstore::mongo::storage::{Engine, EngineOptions, LocalDir, StorageDir};
use hpcstore::util::fmt::human_count;

fn doc(i: u64) -> Document {
    Document::new()
        .set("ts", i as i64)
        .set("node_id", (i % 256) as i64)
        .set("m0", i as f64 * 0.5)
        .set("m1", (i * 7) as f64)
        .set("m2", (i * 13) as f64)
}

fn main() {
    let sizes: &[u64] = if quick_mode() {
        &[2_000, 8_000]
    } else {
        &[2_000, 8_000, 32_000, 64_000]
    };

    let mut report = Report::new(
        "Recovery — replay cost vs ingested volume, before/after checkpoint compaction",
    );
    report.set_custom(
        [
            "docs",
            "journal",
            "recover (full replay)",
            "frames replayed",
            "recover (post-ckpt)",
            "tail frames",
            "speedup",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect(),
    );

    for &n in sizes {
        // Build a journaled store of n synced documents, never
        // checkpointed — the walltime-kill worst case.
        let dir = LocalDir::temp(&format!("figrec-{n}")).unwrap();
        let root = dir.describe();
        {
            let mut eng = Engine::open(Box::new(dir), true, false).unwrap();
            eng.create_collection("metrics");
            let mut i = 0u64;
            while i < n {
                let batch: Vec<Document> = (i..(i + 512).min(n)).map(doc).collect();
                i += batch.len() as u64;
                eng.insert_many("metrics", &batch).unwrap();
                eng.sync().unwrap();
            }
        }

        // (a) Recover from the full journal.
        let t = Instant::now();
        let eng =
            Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        let full_ns = t.elapsed().as_nanos() as u64;
        assert_eq!(eng.stats("metrics").docs, n);
        let full = eng.recovery_report().clone();
        drop(eng);

        // (b) Compact, add a small tail, then recover again: replay is
        // tail-only.
        {
            let mut eng =
                Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
            eng.checkpoint().unwrap();
            let tail: Vec<Document> = (n..n + 64).map(doc).collect();
            eng.insert_many("metrics", &tail).unwrap();
            eng.sync().unwrap();
        }
        let t = Instant::now();
        let eng =
            Engine::open(Box::new(LocalDir::new(&root).unwrap()), true, false).unwrap();
        let ckpt_ns = t.elapsed().as_nanos() as u64;
        assert_eq!(eng.stats("metrics").docs, n + 64);
        let tail = eng.recovery_report().clone();
        assert!(
            tail.bytes_replayed < full.bytes_replayed,
            "compaction must shrink the replay"
        );

        report.add_row(vec![
            human_count(n),
            format!("{} B", human_count(full.bytes_replayed)),
            format!("{:.2} ms", full_ns as f64 / 1e6),
            full.frames_replayed.to_string(),
            format!("{:.2} ms", ckpt_ns as f64 / 1e6),
            tail.frames_replayed.to_string(),
            format!("{:.1}x", full_ns as f64 / ckpt_ns.max(1) as f64),
        ]);
    }
    report.print();
    println!(
        "\nclaim: with compaction, recovery replays only the post-checkpoint tail \
         (frames column) instead of the full write history\n"
    );

    // --- Chain-length axis: base corpus + K delta generations. -------
    let (base_docs, delta_docs): (u64, u64) = if quick_mode() {
        (4_000, 256)
    } else {
        (16_000, 512)
    };
    let chains: &[u64] = if quick_mode() { &[0, 4] } else { &[0, 2, 8, 16] };

    let mut report = Report::new(
        "Recovery — delta-chain length vs checkpoint cost and recovery fold",
    );
    report.set_custom(
        [
            "chain K",
            "ckpt bytes/gen (delta)",
            "full snapshot",
            "deltas folded",
            "fold bytes",
            "tail frames",
            "recover",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect(),
    );

    for &k in chains {
        // Manual lifecycle with a rebase threshold the sweep never
        // reaches, so the chain holds exactly K deltas at kill time.
        let opts = EngineOptions {
            journal: true,
            compress_checkpoints: false,
            checkpoint_bytes: 0,
            journal_segments: 4,
            full_checkpoint_chain: (k + 1).max(1) as u32,
            ..EngineOptions::default()
        };
        let dir = LocalDir::temp(&format!("figrec-chain-{k}")).unwrap();
        let root = dir.describe();
        let full_bytes;
        let mut delta_bytes_last = 0u64;
        {
            let mut eng = Engine::open_with(Box::new(dir), opts.clone()).unwrap();
            eng.create_collection("metrics");
            let mut i = 0u64;
            while i < base_docs {
                let batch: Vec<Document> =
                    (i..(i + 512).min(base_docs)).map(doc).collect();
                i += batch.len() as u64;
                eng.insert_many("metrics", &batch).unwrap();
                eng.sync().unwrap();
            }
            let ck = eng.checkpoint().unwrap(); // generation 1: full
            assert!(ck.full);
            full_bytes = ck.checkpoint_bytes;
            for g in 0..k {
                let lo = base_docs + g * delta_docs;
                let batch: Vec<Document> = (lo..lo + delta_docs).map(doc).collect();
                eng.insert_many("metrics", &batch).unwrap();
                eng.sync().unwrap();
                let ck = eng.checkpoint().unwrap();
                assert!(!ck.full, "chain generation {} must be a delta", ck.generation);
                delta_bytes_last = ck.delta_bytes;
            }
            // Journal tail beyond the newest generation, then kill.
            let lo = base_docs + k * delta_docs;
            let tail: Vec<Document> = (lo..lo + 64).map(doc).collect();
            eng.insert_many("metrics", &tail).unwrap();
            eng.sync().unwrap();
        }
        let t = Instant::now();
        let eng =
            Engine::open_with(Box::new(LocalDir::new(&root).unwrap()), opts).unwrap();
        let ns = t.elapsed().as_nanos() as u64;
        assert_eq!(
            eng.stats("metrics").docs,
            base_docs + k * delta_docs + 64,
            "chain {k}: recovery must be exact"
        );
        let rep = eng.recovery_report().clone();
        assert_eq!(rep.deltas_folded, k);
        report.add_row(vec![
            k.to_string(),
            if k == 0 {
                "-".to_string()
            } else {
                format!("{} B", human_count(delta_bytes_last))
            },
            format!("{} B", human_count(full_bytes)),
            rep.deltas_folded.to_string(),
            format!("{} B", human_count(rep.delta_bytes_folded)),
            rep.frames_replayed.to_string(),
            format!("{:.2} ms", ns as f64 / 1e6),
        ]);
    }
    report.print();
    println!(
        "\nclaim: steady-state checkpoint cost is O(new writes) — the per-generation \
         delta bytes do not grow with the live set — while recovery folds base + K \
         deltas + tail, the trade `full_checkpoint_chain` tunes\n"
    );
}
